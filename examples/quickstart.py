"""Quickstart: the paper's contribution in ~40 lines.

Builds the DeepSeek-R1 decode-attention workload (16 heads × 576-dim latent
vs a long KV context), runs it through the ETAP (transposed) pipeline and
the standard pipeline, and checks they agree with the fp64 oracle — then
shows the Pallas TPU kernel (interpret mode on CPU) doing the same.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.etap import etap_decode_xla, standard_decode_xla
from repro.kernels.etap import ops as etap_ops
from repro.kernels.etap.ref import etap_decode_ref

# DeepSeek-R1 single-instance decode geometry (paper §4.1):
BATCH, HEADS, LATENT, DV, CONTEXT = 16, 16, 576, 512, 4096

rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(size=(BATCH, HEADS, LATENT)), jnp.float32)
latent_cache = jnp.asarray(rng.normal(size=(BATCH, CONTEXT, LATENT)), jnp.float32)
v = latent_cache[..., :DV]          # MLA: V is a view of the latent stream
scale = LATENT ** -0.5

# 1. ETAP: Sᵀ = K·Qᵀ; softmax over columns; Oᵀ = Vᵀ·Pᵀ; O = (Oᵀ)ᵀ
o_etap = etap_decode_xla(q, latent_cache, v, None, scale=scale)

# 2. baseline: S = Q·Kᵀ; softmax over rows; O = P·V
o_std = standard_decode_xla(q, latent_cache, v, None, scale=scale)

# 3. Pallas TPU kernel (MLA-fused: one latent HBM stream serves K and V)
o_kernel = etap_ops.etap_decode_mla(q, latent_cache, DV, None, scale=scale)

# 4. the direct mathematical oracle
o_ref = etap_decode_ref(q, latent_cache, v, None, scale=scale)

for name, o in (("ETAP (XLA)", o_etap), ("standard (XLA)", o_std),
                ("ETAP Pallas kernel", o_kernel)):
    err = float(jnp.max(jnp.abs(o - o_ref)))
    print(f"{name:22s} max|err| vs oracle = {err:.2e}")
    assert err < 1e-4

print("\nAll three pipelines agree — the transposition changes the compute "
      "schedule, not the function. See benchmarks/ for Fig.1/Table-1 and "
      "EXPERIMENTS.md for the TPU roofline study.")
