"""End-to-end training example: train a (reduced) smollm-360m for a few
hundred steps on the synthetic pipeline with checkpointing — then kill it
mid-run and restart, demonstrating the fault-tolerance path.

    PYTHONPATH=src python examples/train_smollm.py
"""
import shutil
import tempfile

from repro.launch import train
from repro.runtime.fault_tolerance import WorkerFailure

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
base = ["--arch", "smollm_360m", "--reduced", "--steps", "300",
        "--batch", "8", "--seq", "64", "--ckpt-dir", ckpt_dir,
        "--ckpt-every", "50", "--log-every", "50", "--n-micro", "2"]

print("=== phase 1: training crashes at step 120 (injected) ===")
try:
    train.run(train.parse_args(base + ["--fail-at", "120"]))
except WorkerFailure as e:
    print(f"worker died: {e}")

print("\n=== phase 2: restart from the latest committed checkpoint ===")
out = train.run(train.parse_args(base + ["--restart"]))
print(f"\nfinal nll={out['losses'][-1]:.4f} "
      f"(started {out['losses'][0]:.4f}); stragglers={out['stragglers']}")
shutil.rmtree(ckpt_dir, ignore_errors=True)
