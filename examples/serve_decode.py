"""Serving example: batched prefill + ETAP autoregressive decode on the
paper's own architecture (reduced deepseek-r1 MLA+MoE), comparing the ETAP
and standard decode pipelines token-for-token, then replaying the same
decode against the PAGED block-pool KV cache.

    PYTHONPATH=src python examples/serve_decode.py

Paged serving (`--cache-layout paged`, the default of the serve driver):

    PYTHONPATH=src python -m repro.launch.serve --reduced \
        --cache-layout paged --batch 4 --prompt 64 --gen 32 --requests 8

The paged layout stores the latent cache as a pool of fixed-size KV blocks
(`--page-size`, default 64 like FlashMLA) indexed through a per-sequence
block table, so ragged-length requests are admitted into free batch slots
whenever the allocator can reserve their token budget and leave the batch
the moment they finish — continuous batching, with true-tokens-served
throughput accounting.  Prompts run as CHUNKED paged prefill
(`--prefill-chunk` tokens at a time, written straight into the pool blocks)
interleaved with the decode batch under a per-step `--token-budget`, so
admitting a long prompt never stalls in-flight decodes.  `--cache-layout
dense` keeps the legacy fixed-batch scan.  Below: the paged cache is a
*layout* change, not a model change — per-step logits match the dense path
to float noise, with the paged cache built by chunked prefill alone.

Requests sharing a prompt prefix (``--shared-prefix``) additionally share
the prefix's KV blocks through a radix-tree prefix cache
(``--prefix-cache``, on by default; DESIGN.md §10): matched blocks are
mapped by refcount bump, their prefill is skipped outright, and the final
leg proves the decoded tokens are bitwise identical with the cache on and
off.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model
from repro.runtime import paged_cache as pc

cfg = reduced(get_config("deepseek_r1_671b"))
params = model.init(jax.random.PRNGKey(0), cfg)

B, PROMPT, GEN = 4, 48, 16
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab_size)

logits, cache, pos = model.prefill(params, cfg, {"tokens": tokens},
                                   max_len=PROMPT + GEN)
print(f"prefilled {B}x{PROMPT} tokens; latent cache entries:",
      sum(x.size for x in jax.tree.leaves(cache)))

outs = {}
for mode in ("etap", "standard"):
    c, cur, toks = cache, jnp.argmax(logits, axis=-1), []
    for i in range(GEN):
        toks.append(cur)
        lg, c = model.decode_step(params, cfg, c, cur, pos + i, mode=mode)
        cur = jnp.argmax(lg, axis=-1)
    outs[mode] = jnp.stack(toks, 1)
    print(f"{mode:9s} generated: {outs[mode][0].tolist()}")

assert (outs["etap"] == outs["standard"]).all(), "pipelines must agree"
print("\nETAP and standard pipelines generate IDENTICAL tokens — the "
      "transposition is a schedule change, not a model change.")

# ---- replay the same decode against the paged block-pool cache ----------
# MoE is dropped for this comparison: the top-k router is discontinuous, so
# float-noise between the two layouts' summation orders can flip an expert
# at a near-tie gate — an O(1e-2) logit jump unrelated to the cache layout.
import dataclasses

cfg_p = dataclasses.replace(cfg, moe=None)
params_p = model.init(jax.random.PRNGKey(0), cfg_p)
_, dense_c, _ = model.prefill(params_p, cfg_p, {"tokens": tokens},
                              max_len=PROMPT + GEN)
layout = pc.layout_for(B, PROMPT + GEN, block_size=16)
bp = pc.BlockPool(layout, B)
paged = model.init_paged_cache(cfg_p, layout)
for b in range(B):
    slot = bp.admit(0, PROMPT + GEN)         # cold admission: blocks only
    assert slot == b
# chunked prefill straight into the pool blocks — one chunk straddles a
# page boundary (16-token pages, 13-token chunk), none stage a dense cache
CHUNK = 13
for lo in range(0, PROMPT, CHUNK):
    hi = min(lo + CHUNK, PROMPT)
    table, lengths = bp.device_views()
    _, paged = model.prefill_chunk(params_p, cfg_p, paged,
                                   tokens[:, lo:hi], table, lengths)
    for b in range(B):
        bp.extend(b, hi - lo)
print(f"chunked paged prefill: {PROMPT} tokens in {-(-PROMPT // CHUNK)} "
      f"chunks of <= {CHUNK} across {layout.block_size}-token pages")

# teacher-force the ETAP token stream through the paged cache and compare
# per-step logits (greedy re-decoding would amplify near-tie argmax flips)
max_dlogit = 0.0
for i in range(GEN):
    tok = outs["etap"][:, i]
    lg_dense, dense_c = model.decode_step(params_p, cfg_p, dense_c, tok,
                                          pos + i)
    table, lengths = bp.device_views()
    lg_paged, paged = model.decode_step(params_p, cfg_p, paged, tok, None,
                                        cache_layout="paged",
                                        block_table=table, lengths=lengths)
    for b in range(B):
        bp.append(b)
    max_dlogit = max(max_dlogit,
                     float(jnp.abs(lg_paged - lg_dense).max()))
assert max_dlogit < 1e-3, max_dlogit
print(f"paged KV cache reproduces the dense pipeline: max |Δlogit| = "
      f"{max_dlogit:.2e} over {GEN} steps, {layout.num_blocks - 1} blocks "
      f"of {layout.block_size} tokens — paging is a LAYOUT change, not a "
      "model change.")
for b in range(B):
    bp.release(b)
assert bp.num_free == layout.num_blocks - 1
print("all", bp.num_free, "blocks returned to the free list on release.")

# ---- radix-tree prefix cache: the cheapest prefill is the skipped one ----
# Three requests share a 16-token system prompt (block-aligned at 8-token
# pages).  With --prefix-cache (the serve default) the first request
# prefills and caches the shared blocks; the other two map them by
# refcount bump and prefill only their tails — and because the match is
# chunk-aligned too, the decoded tokens are BITWISE what the uncached run
# produces.  batch=1 serializes requests so every later one can hit.
from repro.launch import serve

print("\n--- prefix cache: shared system prompt, 3 requests ---")
argv = ["--reduced", "--batch", "1", "--prompt", "24", "--gen", "4",
        "--requests", "3", "--page-size", "8", "--prefill-chunk", "8",
        "--shared-prefix", "16", "--cache-layout", "paged"]
res_on = serve.run_paged(serve.parse_args(argv), cfg_p)
res_off = serve.run_paged(serve.parse_args(argv + ["--no-prefix-cache"]),
                          cfg_p)
assert res_on["outputs"] == res_off["outputs"], \
    "prefix sharing must not change a single decoded token"
assert res_on["prefill_tokens"] + res_on["prefill_tokens_saved"] \
    == res_off["prefill_tokens"]
ps = res_on["prefix"]
print(f"prefix cache ON : {res_on['prefill_tokens']} prompt tokens run + "
      f"{res_on['prefill_tokens_saved']} skipped; hit rate "
      f"{ps['hit_rate']:.0%} ({ps['hits']}/{ps['lookups']}), "
      f"{ps['cached_blocks']} blocks cached, {ps['evictions']} evicted; "
      f"{res_on['refusals']} admission refusals")
print(f"prefix cache OFF: {res_off['prefill_tokens']} prompt tokens run; "
      f"decoded outputs BITWISE identical — prefix sharing is a "
      f"scheduling change, not a model change.")
