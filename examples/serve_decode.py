"""Serving example: batched prefill + ETAP autoregressive decode on the
paper's own architecture (reduced deepseek-r1 MLA+MoE), comparing the ETAP
and standard decode pipelines token-for-token.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model

cfg = reduced(get_config("deepseek_r1_671b"))
params = model.init(jax.random.PRNGKey(0), cfg)

B, PROMPT, GEN = 4, 48, 16
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, PROMPT), 0, cfg.vocab_size)

logits, cache, pos = model.prefill(params, cfg, {"tokens": tokens},
                                   max_len=PROMPT + GEN)
print(f"prefilled {B}x{PROMPT} tokens; latent cache entries:",
      sum(x.size for x in jax.tree.leaves(cache)))

outs = {}
for mode in ("etap", "standard"):
    c, cur, toks = cache, jnp.argmax(logits, axis=-1), []
    for i in range(GEN):
        toks.append(cur)
        lg, c = model.decode_step(params, cfg, c, cur, pos + i, mode=mode)
        cur = jnp.argmax(lg, axis=-1)
    outs[mode] = jnp.stack(toks, 1)
    print(f"{mode:9s} generated: {outs[mode][0].tolist()}")

assert (outs["etap"] == outs["standard"]).all(), "pipelines must agree"
print("\nETAP and standard pipelines generate IDENTICAL tokens — the "
      "transposition is a schedule change, not a model change.")
