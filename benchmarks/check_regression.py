"""Benchmark-regression gate: diff a fresh BENCH_*.json against a committed
baseline and fail red when throughput regressed past the tolerance.

CI (the smoke job) stashes the committed baselines before running
``benchmarks/run.py --smoke``, then gates the fresh artifacts:

    python benchmarks/check_regression.py \
        --baseline .bench-baseline/BENCH_smoke.json --fresh BENCH_smoke.json

Runs locally the same way.

Gate criterion: the GEOMETRIC MEAN of per-row fresh/baseline time ratios
must stay under 1 + tolerance (default +20%).  Per-row ratios are printed
and flagged, but a single row does not trip the gate: shared CI runners
have heavy-tailed scheduler noise that can double one row of an unchanged
binary, while a real regression (the injected-30% self-test, a de-optimized
kernel on the hot path) moves the whole distribution.  ``--per-row`` opts
into the strict mode for quiet machines.  Rows faster than ``--min-us`` on
either side are excluded — microsecond rows are pure timer noise.

Exit codes: 0 green; 1 regression (geomean past tolerance, or a baseline
row missing from the fresh run); 2 refusal — schema_version / config
mismatch means the artifacts are incompatible and are never silently
diffed (regenerate with ``benchmarks/run.py --smoke`` and commit).
``--inject-slowdown 1.3`` scales the fresh timings to prove the gate
trips (the CI self-test and the PR-description demo)."""
from __future__ import annotations

import argparse
import json
import math
import sys

REQUIRED_META = ("schema_version", "config")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _rows(doc: dict) -> dict:
    # us == 0 rows are artifact markers ("smoke/json"), not measurements
    return {r["name"]: float(r["us"]) for r in doc.get("rows", [])
            if float(r["us"]) > 0.0}


def compare(base: dict, fresh: dict, *, tolerance: float,
            inject_slowdown: float = 1.0, min_us: float = 1000.0,
            per_row: bool = False) -> int:
    bm, fm = base.get("meta", {}), fresh.get("meta", {})
    for key in REQUIRED_META:
        if bm.get(key) != fm.get(key):
            print(f"REFUSED: baseline {key}={bm.get(key)!r} vs fresh "
                  f"{key}={fm.get(key)!r} — incompatible artifacts; "
                  f"regenerate + commit the baseline instead of diffing.")
            return 2
    if bm.get("jax_version") != fm.get("jax_version"):
        print(f"note: jax {bm.get('jax_version')} (baseline) vs "
              f"{fm.get('jax_version')} (fresh) — comparing anyway")
    print(f"baseline sha={bm.get('git_sha')}  fresh sha={fm.get('git_sha')}"
          f"  tolerance=+{tolerance:.0%}"
          + (f"  INJECTED x{inject_slowdown}" if inject_slowdown != 1.0
             else ""))

    rb, rf = _rows(base), _rows(fresh)
    missing = sorted(set(rb) - set(rf))
    for name in missing:
        print(f"MISSING  {name}: in baseline but not in fresh run "
              f"(renames must regenerate the baseline)")
    print(f"{'row':44s} {'base_us':>10s} {'fresh_us':>10s} {'ratio':>7s}")
    ratios, slow = [], []
    for name in sorted(rb.keys() & rf.keys()):
        us = rf[name] * inject_slowdown
        if rb[name] < min_us or rf[name] < min_us:
            print(f"{name:44s} {rb[name]:10.1f} {us:10.1f}    —   "
                  f"(< {min_us:.0f}us noise floor, ungated)")
            continue
        ratio = us / rb[name]
        ratios.append(ratio)
        flag = ("SLOW   " if ratio > 1 + tolerance else
                "faster " if ratio < 1 - tolerance else "ok     ")
        print(f"{name:44s} {rb[name]:10.1f} {us:10.1f} {ratio:6.2f}x {flag}")
        if ratio > 1 + tolerance:
            slow.append(name)
    for name in sorted(set(rf) - set(rb)):
        print(f"new      {name}: {rf[name]:.1f}us (no baseline; add one by "
              f"committing the fresh artifact)")

    geomean = math.exp(sum(map(math.log, ratios)) / len(ratios)) \
        if ratios else 1.0
    print(f"\ngeomean ratio over {len(ratios)} gated rows: {geomean:.3f}x "
          f"(gate: <= {1 + tolerance:.2f}x)"
          + (f"; {len(slow)} row(s) individually past tolerance: "
             f"{', '.join(slow)}" if slow else ""))
    failed = bool(missing) or geomean > 1 + tolerance \
        or (per_row and bool(slow))
    if failed:
        print(f"FAIL: throughput regressed past +{tolerance:.0%} vs the "
              f"committed baseline.")
        if len(ratios) > 1:
            # near-uniform shift = every row slowed by ~the same factor —
            # the signature of a slower MACHINE (baseline from different
            # hardware), indistinguishable in principle from a uniform code
            # regression. Surface it so a first run on new CI hardware is
            # diagnosed in one read.
            logs = [math.log(r) for r in ratios]
            mean = sum(logs) / len(logs)
            sd = math.sqrt(sum((x - mean) ** 2 for x in logs) / len(logs))
            if sd < 0.15:
                print("note: the slowdown is near-uniform across rows — "
                      "this is what a slower machine looks like. If the "
                      "baseline was generated on different hardware, "
                      "commit the fresh artifact (uploaded by the smoke "
                      "job) as the new baseline.")
        return 1
    print("OK: no regression past the tolerance.")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="committed baseline BENCH_*.json")
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional slowdown (default 0.2 = +20%%)")
    ap.add_argument("--min-us", type=float, default=1000.0,
                    help="rows faster than this on either side are ungated")
    ap.add_argument("--per-row", action="store_true",
                    help="also fail when any single row is past tolerance")
    ap.add_argument("--inject-slowdown", type=float, default=1.0,
                    help="scale fresh timings — self-test that the gate "
                         "actually trips (e.g. 1.3 must exit 1)")
    args = ap.parse_args(argv)
    return compare(_load(args.baseline), _load(args.fresh),
                   tolerance=args.tolerance,
                   inject_slowdown=args.inject_slowdown,
                   min_us=args.min_us, per_row=args.per_row)


if __name__ == "__main__":
    sys.exit(main())
