#!/usr/bin/env python
"""DEPRECATED shim — this lint is now ``repro.analysis`` rule REPRO009.

The bare-print check (runtime/serving numbers flow through the telemetry
registry, DESIGN.md §15) moved into the unified invariant analyzer
(DESIGN.md §16) with the rest of the AST lints.  This file is kept so
local scripts and docs pointing at the old path keep working; it just
runs the analyzer restricted to the ported rule:

    python -m repro.analysis --select REPRO009
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.analysis import cli  # noqa: E402

if __name__ == "__main__":
    print("benchmarks/lint_prints.py is deprecated; running "
          "`python -m repro.analysis --select REPRO009`", file=sys.stderr)
    sys.exit(cli.main(["--select", "REPRO009"]))
