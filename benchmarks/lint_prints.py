#!/usr/bin/env python
"""AST lint: runtime/serving code reports through telemetry, not print().

The observability layer (src/repro/runtime/telemetry.py +
src/repro/launch/obs.py, DESIGN.md §15) exists so every number the serving
stack emits flows through ONE snapshot: counters/gauges/histograms land in
the MetricsRegistry, human-readable summaries render from that snapshot via
``obs.summarize_*`` and print through ``obs.emit``.  A bare ``print(`` in
the runtime or the serve loop is a stat that escaped the registry — it
can't be exported by ``--metrics-out``, can't be asserted by tests, and
drifts from the summary the next time someone edits one but not the other.

This lint fails (exit 1) on any ``print(...)`` call in
``src/repro/runtime/`` or ``src/repro/launch/serve.py``.  The sanctioned
sinks are allow-listed: telemetry.py itself (it owns no stats — but keep
the door open for a debug dump) and launch/obs.py's ``emit``.  stdlib-only:
runs in the CI lint job before any heavyweight deps are installed.
"""
from __future__ import annotations

import ast
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SCAN = [REPO / "src" / "repro" / "runtime",
        REPO / "src" / "repro" / "launch" / "serve.py"]
ALLOWED = {REPO / "src" / "repro" / "runtime" / "telemetry.py"}


def _check_file(path: pathlib.Path) -> list[str]:
    tree = ast.parse(path.read_text(), filename=str(path))
    errors = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"):
            rel = (path.relative_to(REPO) if path.is_relative_to(REPO)
                   else path)
            errors.append(
                f"{rel}:{node.lineno}: bare print() in runtime/serving "
                f"code — record the number in the MetricsRegistry and "
                f"render it via launch/obs.summarize_* / obs.emit "
                f"(DESIGN.md §15)")
    return errors


def main() -> int:
    errors = []
    for root in SCAN:
        paths = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for path in paths:
            if path in ALLOWED:
                continue
            errors.extend(_check_file(path))
    if errors:
        print("\n".join(errors))
        print(f"\nlint_prints: {len(errors)} stray print(s); runtime stats "
              f"belong in runtime/telemetry.py's registry")
        return 1
    print("lint_prints: ok — no bare print() in src/repro/runtime/ or "
          "launch/serve.py")
    return 0


if __name__ == "__main__":
    sys.exit(main())
