#!/usr/bin/env python
"""AST lint: exactly ONE online-softmax rescale definition in the tree.

Before DESIGN.md §13 the ``(m, l, acc)`` rescale chain — ``exp(x - m_new)``
correction weights feeding an ``acc * corr + update`` accumulate — was
hand-copied across five kernel bodies, their XLA twins, and two split
combiners, and the copies drifted (the PR 5 bf16-stat bug lived in exactly
one of them).  The one true definition now lives in
``src/repro/kernels/softmax_state.py``; every kernel calls it.

This lint fails (exit 1) on any FUNCTION outside that module whose body
contains BOTH halves of the chain:

  1. an ``exp``/``exp2`` call whose argument subtracts something — the
     rescale correction / shifted-softmax weight ``exp(x - m)``; and
  2. an assignment of the form ``y = a * b + c`` (or ``y += a * b``) — the
     rescaled accumulate.

Either half alone is fine (oracles call ``jax.nn.softmax``; rooflines do
mul-adds); both in one function is an online-softmax recurrence that
belongs behind the shared API.  stdlib-only: runs in the CI lint job
before any heavyweight deps are installed.
"""
from __future__ import annotations

import ast
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SCAN_ROOTS = ("src/repro", "benchmarks")
ALLOWED = {REPO / "src" / "repro" / "kernels" / "softmax_state.py"}
EXP_NAMES = {"exp", "exp2"}


def _is_exp_of_sub(node: ast.AST) -> bool:
    """``*.exp(... - ...)`` / ``exp2(... - ...)`` — a shifted exponential."""
    if not isinstance(node, ast.Call) or not node.args:
        return False
    fn = node.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else "")
    if name not in EXP_NAMES:
        return False
    return any(isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Sub)
               for sub in ast.walk(node.args[0]))


def _is_mul_add_store(node: ast.AST) -> bool:
    """``y = a * b + c`` or ``y += a * b`` — a rescaled accumulate."""
    if isinstance(node, (ast.Assign, ast.AnnAssign)):
        v = node.value
        return (isinstance(v, ast.BinOp) and isinstance(v.op, ast.Add)
                and any(isinstance(s, ast.BinOp)
                        and isinstance(s.op, ast.Mult)
                        for s in (v.left, v.right)))
    if isinstance(node, ast.AugAssign):
        return (isinstance(node.op, ast.Add)
                and isinstance(node.value, ast.BinOp)
                and isinstance(node.value.op, ast.Mult))
    return False


def _check_file(path: pathlib.Path) -> list[str]:
    tree = ast.parse(path.read_text(), filename=str(path))
    errors = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # a nested def owns its own body: don't double-report the parent
        body = [n for child in node.body for n in ast.walk(child)
                if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                or n in node.body]
        has_exp = any(_is_exp_of_sub(n) for n in body)
        has_acc = any(_is_mul_add_store(n) for n in body)
        if has_exp and has_acc:
            rel = path.relative_to(REPO) if path.is_relative_to(REPO) else path
            errors.append(
                f"{rel}:{node.lineno}: function "
                f"`{node.name}` hand-rolls an online-softmax rescale chain "
                f"(exp-of-difference + mul-add accumulate); use "
                f"repro.kernels.softmax_state instead (DESIGN.md §13)")
    return errors


def main() -> int:
    errors = []
    for root in SCAN_ROOTS:
        for path in sorted((REPO / root).rglob("*.py")):
            if path in ALLOWED:
                continue
            errors.extend(_check_file(path))
    if errors:
        print("\n".join(errors))
        print(f"\nlint_softmax: {len(errors)} hand-rolled rescale chain(s); "
              f"the one true definition is kernels/softmax_state.py")
        return 1
    print("lint_softmax: ok — no rescale chains outside softmax_state.py")
    return 0


if __name__ == "__main__":
    sys.exit(main())
