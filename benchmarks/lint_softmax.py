#!/usr/bin/env python
"""DEPRECATED shim — this lint is now ``repro.analysis`` rule REPRO002.

The softmax-rescale-chain check (no exp-of-difference + mul-add
accumulate outside ``kernels/softmax_state.py``) moved into the unified
invariant analyzer (DESIGN.md §16) with the rest of the AST lints.  This
file is kept so local scripts and docs pointing at the old path keep
working; it just runs the analyzer restricted to the ported rule:

    python -m repro.analysis --select REPRO002
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.analysis import cli  # noqa: E402

if __name__ == "__main__":
    print("benchmarks/lint_softmax.py is deprecated; running "
          "`python -m repro.analysis --select REPRO002`", file=sys.stderr)
    sys.exit(cli.main(["--select", "REPRO002"]))
