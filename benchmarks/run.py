"""Benchmark harness entry point — one function per paper artifact.
Prints ``name,us_per_call,derived`` CSV rows (derived = the artifact's
headline metric).  ``--kv-splits`` runs the split-KV decode sweep instead
and records per-split-count results to BENCH_splitkv.json.  ``--smoke``
runs the fast CI subset (kernel interpret paths + paged cache + prefix
cache + the multi-tenant scheduler + speculation + the telemetry layer +
a tiny split-KV sweep) and records BENCH_smoke.json + BENCH_prefix.json
+ BENCH_serve.json + BENCH_spec.json + BENCH_obs.json +
BENCH_smoke_splitkv.json — the per-PR perf-trajectory artifacts the CI
smoke job uploads."""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

# v2: rescale-mode era — kernels default to AMLA deferred rescaling
# (kernels/softmax_state.py) and bench_kernels_interpret carries a
# mul-referee comparison row; v1 baselines are not comparable.
SCHEMA_VERSION = 2


def bench_meta(config: str) -> dict:
    """Schema-versioned provenance stamp for BENCH_*.json artifacts.

    The regression gate (benchmarks/check_regression.py) refuses to diff
    files whose schema_version or config name disagree — comparing a
    reshaped artifact against an old baseline silently would turn the gate
    into noise.  git sha and jax version are informational (recorded so a
    red diff can be traced to its commit/toolchain, not compat-checked:
    the whole point of the gate is comparing across shas)."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    return {"schema_version": SCHEMA_VERSION, "config": config,
            "git_sha": sha or "unknown", "jax_version": jax.__version__}


def bench_fig1_throughput():
    """Paper Fig. 1 (reduced sweep): ETAP vs standard decode pipelines."""
    from benchmarks.fig1_throughput import run
    rows = run(full=False)
    out = []
    for r in rows:
        out.append((f"fig1/etap/bs{r['batch']}/s{r['seq']}", r["etap_us"],
                    f"{r['etap_gflops']:.2f}GF/s"))
        out.append((f"fig1/standard/bs{r['batch']}/s{r['seq']}", r["std_us"],
                    f"speedup={r['speedup']:.2f}x"))
    return out


def bench_table1_rmse():
    """Paper Table 1: fp16/bf16 RMSE vs fp64 oracle."""
    from benchmarks.table1_rmse import rmse_for
    jax.config.update("jax_enable_x64", True)
    try:
        out = []
        for dtype, name in ((jnp.float16, "fp16"), (jnp.bfloat16, "bf16")):
            for mode in ("etap", "standard"):
                t0 = time.perf_counter()
                r = rmse_for(16, 2048, dtype, mode)
                dt = (time.perf_counter() - t0) * 1e6
                out.append((f"table1/{name}/{mode}", dt, f"rmse={r:.3e}"))
        return out
    finally:
        jax.config.update("jax_enable_x64", False)


def bench_kernels_interpret():
    """Pallas kernel paths (interpret mode) at the paper geometry.  The
    timed rows run the default rescale mode (amla unless REPRO_RESCALE /
    --rescale overrides); a mul-referee row times the same ETAP kernel
    under multiply-rescale and records the max |amla - mul| divergence."""
    from repro.kernels import softmax_state
    from repro.kernels.etap import ops as etap_ops
    from repro.kernels.flash_decode import ops as fd_ops
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 16, 576)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(4, 2048, 576)), jnp.float32)
    v = k[..., :512]
    out = []
    for name, fn in (("kernel/etap", lambda: etap_ops.etap_decode(
            q, k, v, None, scale=576 ** -0.5, block=512)),
                     ("kernel/etap_mla_fused", lambda: etap_ops.etap_decode_mla(
            q, k, 512, None, scale=576 ** -0.5, block=512)),
                     ("kernel/flash_decode_baseline", lambda: fd_ops.flash_decode(
            q, k, v, None, scale=576 ** -0.5, block=512))):
        out.append((name, _best_of(fn), "interpret=True"))
    # mul-vs-amla referee: same kernel, flag-selected rescale modes
    o_amla = etap_ops.etap_decode(q, k, v, None, scale=576 ** -0.5,
                                  block=512, rescale="amla")
    o_mul = etap_ops.etap_decode(q, k, v, None, scale=576 ** -0.5,
                                 block=512, rescale="mul")
    div = float(jnp.max(jnp.abs(o_amla - o_mul)))
    out.append(("kernel/etap_rescale_mul", _best_of(
        lambda: etap_ops.etap_decode(q, k, v, None, scale=576 ** -0.5,
                                     block=512, rescale="mul")),
        f"max|amla-mul|={div:.2e};default={softmax_state.default_mode()}"))
    return out


def _best_of(fn, n: int = 3) -> float:
    """us per call, min over n timed calls after one warmup — the robust
    estimator the ±20% regression gate (check_regression.py) diffs."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_serving_e2e():
    """End-to-end reduced-config serving step (deepseek MLA, both modes)."""
    from repro.configs import get_config, reduced
    from repro.models import model
    cfg = reduced(get_config("deepseek_r1_671b"))
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0, cfg.vocab_size)
    _, cache, pos = model.prefill(params, cfg, {"tokens": toks[:, :32]},
                                  max_len=64)
    out = []
    for mode in ("etap", "standard"):
        step = jax.jit(lambda p, c, t, i, m=mode: model.decode_step(
            p, cfg, c, t, i, mode=m))
        logits, c2 = step(params, cache, toks[:, 32], pos)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for i in range(8):
            logits, c2 = step(params, c2, toks[:, 32], pos + 1 + i)
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / 8 * 1e6
        out.append((f"serve/decode_step/{mode}", dt, "reduced deepseek_r1"))
    return out


def bench_paged():
    """Paged vs dense ETAP decode (interpret kernels) at the paper's MLA
    geometry, plus the allocator round-trip → BENCH_paged.json rows."""
    from repro.kernels.etap import ops as etap_ops
    from repro.runtime.paged_cache import BlockPool, dense_to_paged, layout_for

    B, H, DIM, DV, S, page = 2, 16, 576, 512, 1024, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, DIM)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(B, S, DIM)), jnp.float32)
    lengths = np.asarray([S // 2 + 3, S])
    layout = layout_for(B, S, block_size=page)
    pool, bp = dense_to_paged(kv, lengths, layout)
    table, lens = bp.device_views()
    scale = DIM ** -0.5

    timed = _best_of

    rows = []
    rows.append(("kernel/etap_mla_dense", timed(
        lambda: etap_ops.etap_decode_mla(
            q, kv, DV, jnp.asarray(lengths), scale=scale, block=page)),
        f"S={S}"))
    rows.append(("kernel/etap_mla_paged", timed(
        lambda: etap_ops.etap_decode_mla_paged(
            q, pool, DV, table, lens, scale=scale)),
        f"page={page};blocks={layout.num_blocks - 1}"))
    rows.append(("kernel/etap_mla_paged_splitkv", timed(
        lambda: etap_ops.etap_decode_mla_paged_splitkv(
            q, pool, DV, table, lens, scale=scale, n_splits=4)),
        "n_splits=4"))
    # chunked paged prefill: a 16-token chunk tile against the same pool
    # (the last 16 tokens of each sequence play the live chunk)
    CQ = 16
    qc = jnp.asarray(rng.normal(size=(B, CQ, H, DIM)), jnp.float32)
    starts = jnp.asarray(lengths - CQ, jnp.int32)
    rows.append(("kernel/etap_prefill_mla_paged", timed(
        lambda: etap_ops.etap_prefill_mla_paged(
            qc, pool, DV, table, starts, scale=scale)),
        f"chunk={CQ}"))
    t0 = time.perf_counter()
    alloc = BlockPool(layout, B)
    for _ in range(100):
        s0 = alloc.admit(S // 2, S)
        alloc.release(s0)
    rows.append(("paged/alloc_release_roundtrip",
                 (time.perf_counter() - t0) / 100 * 1e6,
                 f"{layout.num_blocks - 1}blocks"))
    with open("BENCH_paged.json", "w") as f:
        json.dump({"meta": bench_meta("paged"),
                   "geometry": {"batch": B, "heads": H, "dim": DIM,
                                "dv": DV, "seq": S, "page": page},
                   "rows": [{"name": n, "us": us, "derived": d}
                            for n, us, d in rows]}, f, indent=2)
    rows.append(("paged/json", 0.0, "BENCH_paged.json"))
    return rows


def bench_prefix():
    """Prefix-cache subsystem (DESIGN.md §10) → BENCH_prefix.json rows.

    Two kinds of rows: host-side radix-tree / shared-admission roundtrips
    are the GATED timings (stable on shared CI runners — they are pure
    Python dict/refcount work, no device dispatch); the shared-system-
    prompt serve SWEEP rows are informational (us=0, excluded from the
    ±20% gate by the noise-floor rule) — their value is the derived
    hit-rate / prefill-tokens-saved trajectory, which is asserted
    self-consistent before the artifact is written."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.launch import serve
    from repro.runtime.paged_cache import BlockPool, PagedLayout
    from repro.runtime.prefix_cache import PrefixCache

    rows = []
    # --- gated: trie insert/match/evict roundtrip at serving scale
    bs, n_seq, nb = 16, 128, 8
    layout = PagedLayout(block_size=bs, num_blocks=1 + n_seq * nb,
                         max_blocks=nb)
    rng = np.random.default_rng(0)
    system = rng.integers(0, 50000, size=(4 * bs,))       # shared sys prompt
    prompts = [np.concatenate([system,
                               rng.integers(0, 50000, size=(4 * bs,))])
               for _ in range(n_seq)]

    def trie_roundtrip():
        bp = BlockPool(layout, n_seq)
        trie = PrefixCache(bs)
        slots = []
        for toks in prompts:
            s = bp.admit(0, len(toks))
            bp.extend(s, len(toks))
            trie.insert(toks, bp.block_ids(s), bp)
            slots.append(s)
        for toks in prompts:
            trie.match(toks)
        for s in slots:
            bp.release(s)
        while trie.evict_lru(bp) is not None:
            pass

    rows.append(("prefix/trie_roundtrip", _best_of(trie_roundtrip),
                 f"{n_seq}seqs x {nb}blocks;page={bs}"))

    # --- gated: cache-aware admission roundtrip (match + refcount bump)
    small = PagedLayout(block_size=bs, num_blocks=1 + 3 * nb, max_blocks=nb)

    def admit_shared_roundtrip():
        bp = BlockPool(small, 2)
        trie = PrefixCache(bs)
        s0 = bp.admit(0, 8 * bs)
        bp.extend(s0, 8 * bs)
        trie.insert(prompts[0], bp.block_ids(s0), bp)
        bp.release(s0)
        for _ in range(200):
            chain, matched = trie.match(prompts[0])
            s, cow = bp.admit_shared(matched, 8 * bs, chain)
            assert not cow
            bp.release(s)

    rows.append(("prefix/admit_shared_x200", _best_of(admit_shared_roundtrip),
                 f"{nb - 1}shared blocks/admit"))

    # --- informational: shared-system-prompt workload sweep through the
    # real serve loop (reduced MLA arch, MoE dropped: bitwise on==off)
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    sweep = {}
    for shared, on in ((0, True), (8, True), (12, True), (8, False)):
        argv = ["--reduced", "--batch", "1", "--prompt", "16", "--gen", "2",
                "--requests", "3", "--page-size", "4", "--prefill-chunk",
                "4", "--cache-layout", "paged",
                "--shared-prefix", str(shared)]
        if not on:
            argv.append("--no-prefix-cache")
        res = serve.run_paged(serve.parse_args(argv), cfg)
        sweep[(shared, on)] = res
        hit = res["prefix"]["hit_rate"] if res["prefix"] else 0.0
        rows.append((f"prefix/serve/shared{shared}/{'on' if on else 'off'}",
                     0.0,
                     f"hit={hit:.2f};pf_tokens={res['prefill_tokens']};"
                     f"saved={res['prefill_tokens_saved']};"
                     f"decode={res['decode_tokens']}"))
    # the artifact must be self-consistent before it becomes a baseline:
    # caching only moves prompt tokens from "run" to "skipped", bitwise
    on8, off8 = sweep[(8, True)], sweep[(8, False)]
    assert on8["outputs"] == off8["outputs"]
    assert on8["prefill_tokens"] + on8["prefill_tokens_saved"] \
        == off8["prefill_tokens"]
    assert on8["prefill_tokens_saved"] > 0

    with open("BENCH_prefix.json", "w") as f:
        json.dump({"meta": bench_meta("prefix"),
                   "geometry": {"page": bs, "seqs": n_seq,
                                "blocks_per_seq": nb},
                   "rows": [{"name": n, "us": us, "derived": str(d)}
                            for n, us, d in rows]}, f, indent=2)
    rows.append(("prefix/json", 0.0, "BENCH_prefix.json"))
    return rows


def bench_quant():
    """Quantized KV layouts (DESIGN.md §11) → BENCH_quant.json rows.

    Two kinds of rows: the timed quantized-kernel paths (gated by the
    ±20% regression gate like every other kernel row) and the RMSE-vs-
    fp32 accuracy rows (us=0, informational in the timing gate) — but the
    accuracy numbers are HARD-asserted here against the acceptance
    budgets (int8 <= 5e-3, fp8 <= 2e-2) before the artifact is written:
    a quantization-accuracy regression fails the bench run itself, not a
    downstream diff."""
    from repro.kernels.etap import ops as etap_ops
    from repro.kernels.etap.ref import etap_decode_ref
    from repro.runtime import paged_cache as pcache

    B, H, DIM, DV, S, page = 2, 16, 576, 512, 1024, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, DIM)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(B, S, DIM)), jnp.float32)
    lengths = np.asarray([S // 2 + 3, S])
    layout = pcache.layout_for(B, S, block_size=page)
    pool, bp = pcache.dense_to_paged(kv, lengths, layout)
    table, lens = bp.device_views()
    scale = DIM ** -0.5
    ref = etap_decode_ref(q, kv, kv[..., :DV], jnp.asarray(lengths),
                          scale=scale)
    budgets = {"int8": 5e-3, "fp8": 2e-2}

    rows = []
    rmse_by_layout = {}
    layouts = ["int8"] + (["fp8"] if pcache.HAS_FP8 else [])
    for kvd in layouts:
        codes, sz = pcache.quantize_pool(pool, kvd)
        rows.append((f"quant/{kvd}/etap_mla_paged", _best_of(
            lambda: etap_ops.etap_decode_mla_paged(
                q, codes, DV, table, lens, scale=scale, kv_sz=sz)),
            f"page={page}"))
        rows.append((f"quant/{kvd}/etap_mla_paged_splitkv", _best_of(
            lambda: etap_ops.etap_decode_mla_paged_splitkv(
                q, codes, DV, table, lens, scale=scale, n_splits=4,
                kv_sz=sz)), "n_splits=4"))
        CQ = 16
        qc = jnp.asarray(rng.normal(size=(B, CQ, H, DIM)), jnp.float32)
        starts = jnp.asarray(lengths - CQ, jnp.int32)
        rows.append((f"quant/{kvd}/etap_prefill_mla_paged", _best_of(
            lambda: etap_ops.etap_prefill_mla_paged(
                qc, codes, DV, table, starts, scale=scale, kv_sz=sz)),
            f"chunk={CQ}"))
        out = etap_ops.etap_decode_mla_paged(q, codes, DV, table, lens,
                                             scale=scale, kv_sz=sz)
        err = np.asarray(out, np.float64) - np.asarray(ref, np.float64)
        rmse = float(np.sqrt(np.mean(err ** 2)))
        rmse_by_layout[kvd] = rmse
        assert rmse <= budgets[kvd], \
            f"{kvd} decode RMSE {rmse:.2e} past the {budgets[kvd]:.0e} budget"
        rows.append((f"quant/{kvd}/rmse_vs_fp32", 0.0,
                     f"rmse={rmse:.3e};budget={budgets[kvd]:.0e}"))

    # capacity: the serve loop's admission lever, asserted not just logged
    from repro.configs import get_config, reduced
    from repro.models import model as model_mod
    cfg = reduced(get_config("deepseek_r1_671b"))
    fp_row = model_mod.paged_row_bytes(cfg, "fp")
    budget = (layout.num_blocks - 1) * page * fp_row
    _, fp_slots = pcache.layout_for_bytes(budget, fp_row, S, block_size=page)
    _, q_slots = pcache.layout_for_bytes(
        budget, model_mod.paged_row_bytes(cfg, "int8"), S, block_size=page)
    assert q_slots >= 1.8 * fp_slots, (q_slots, fp_slots)
    rows.append(("quant/int8/capacity_ratio", 0.0,
                 f"slots={q_slots}vs{fp_slots};x{q_slots / fp_slots:.2f}"))

    with open("BENCH_quant.json", "w") as f:
        json.dump({"meta": bench_meta("quant"),
                   "geometry": {"batch": B, "heads": H, "dim": DIM,
                                "dv": DV, "seq": S, "page": page},
                   "rmse": rmse_by_layout,
                   "rows": [{"name": n, "us": us, "derived": str(d)}
                            for n, us, d in rows]}, f, indent=2)
    rows.append(("quant/json", 0.0, "BENCH_quant.json"))
    return rows


def bench_serve():
    """Multi-tenant scheduler subsystem (DESIGN.md §12) → BENCH_serve.json.

    Two kinds of rows, same split as bench_prefix: the GATED timings are
    pure host-side scheduler/pool roundtrips (admit → preempt → restore
    and swap_out → swap_in at serving scale — no device dispatch, stable
    on shared runners); the trace-driven serve SWEEP rows are
    informational (us=0, under the noise-floor rule) and carry the
    per-priority-class p50/p99 TTFT/ITL tails plus preemption counts.
    The acceptance criteria are HARD-asserted before the artifact is
    written: under a ~2x over-subscribed burst trace every request
    completes (zero permanent refusals) and greedy outputs are BITWISE
    identical to an uncontended run, for both evacuation modes."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.launch import serve
    from repro.runtime import scheduler as sch
    from repro.runtime.paged_cache import BlockPool, PagedLayout
    from repro.runtime.prefix_cache import PrefixCache

    rows = []
    # --- gated: admit -> preempt (recompute) -> restore roundtrip.  Half
    # the requests fit; the other half arrive at higher priority and evict
    # them; the victims re-admit as slots drain — every path in the policy
    # (victim selection, pin/unpin, backoff, idle kick) runs host-side.
    bs, nb, n_seq = 16, 8, 64
    layout = PagedLayout(block_size=bs, num_blocks=1 + (n_seq // 2) * nb,
                         max_blocks=nb)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 50000, size=(4 * bs,)) for _ in range(n_seq)]

    def sched_preempt_roundtrip():
        bp = BlockPool(layout, n_seq // 2)
        sched = sch.Scheduler(bp, PrefixCache(bs))
        for i, toks in enumerate(prompts):
            late = i >= n_seq // 2        # high class arrives second and
            sched.add(sch.Request(id=i, prompt=toks, gen=4 * bs,
                                  priority=0 if late else 1,
                                  arrival=int(late)))     # evicts the first
        for tick in range(3):
            sched.admit(tick)             # fill; then evict the low class
        while sched.queue:                # drain: finish runners, restore
            for r in list(sched.by_slot.values()):
                r.remaining = 0
                r.replay.clear()
                sched.finish(r)
            sched.admit(tick)
            tick += 1
        for r in list(sched.by_slot.values()):
            r.remaining = 0
            r.replay.clear()
            sched.finish(r)
        assert len(sched.done) == n_seq
        assert sched.stats()["preemptions"] > 0

    rows.append(("serve/sched_preempt_roundtrip",
                 _best_of(sched_preempt_roundtrip),
                 f"{n_seq}reqs through {n_seq // 2}slots x 2 classes"))

    # --- gated: two-tier swap accounting roundtrip (no bytes, pure pool)
    def swap_roundtrip():
        bp = BlockPool(layout, n_seq // 2, host_blocks=(n_seq // 2) * nb)
        for _ in range(50):
            slots = []
            for _ in range(n_seq // 2):
                s = bp.admit(0, nb * bs)
                bp.extend(s, nb * bs)
                slots.append(s)
            for s in slots:
                assert bp.swap_out(s, f"k{s}") is not None
            for s in slots:
                assert bp.swap_in(f"k{s}") is not None
            for s in range(bp.batch_slots):
                if bp.active[s]:
                    bp.release(s)
        bp.check_conservation()

    rows.append(("serve/swap_roundtrip_x50", _best_of(swap_roundtrip),
                 f"{n_seq // 2}slots x {nb}blocks/seq"))

    # --- informational: trace-driven serve sweep through the real loop
    # (reduced MLA arch, MoE dropped: contended == uncontended bitwise)
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    base = ["--reduced", "--prompt", "24", "--gen", "8", "--requests", "6",
            "--page-size", "8", "--prefill-chunk", "8", "--cache-layout",
            "paged", "--priority-classes", "3", "--arrival-rate", "0.25",
            "--trace", "burst", "--burst-size", "3", "--retry-backoff", "4",
            "--paranoia", "4"]
    runs = {}
    for name, argv in (("calm", ["--batch", "8"]),
                       ("recompute", ["--batch", "2",
                                      "--preemption", "recompute"]),
                       ("swap", ["--batch", "2", "--preemption", "swap"])):
        res = serve.run_paged(serve.parse_args(base + argv), cfg)
        runs[name] = res
        s = res["sched"]
        rows.append((f"serve/trace/{name}", 0.0,
                     f"preempts={s['preemptions']};"
                     f"refusals={res['refusals']};"
                     f"replayed={res['replayed_tokens']};"
                     f"served={res['tokens_served']}"))
        for cls, c in res["classes"].items():
            rows.append((f"serve/trace/{name}/class{cls}", 0.0,
                         f"n={c['n']};preempts={c['preemptions']};"
                         f"ttft_p50={c['ttft_p50_ms']:.1f}ms;"
                         f"ttft_p99={c['ttft_p99_ms']:.1f}ms;"
                         f"itl_p50={c['itl_p50_ms']:.2f}ms;"
                         f"itl_p99={c['itl_p99_ms']:.2f}ms"))
    # acceptance, asserted before the artifact can become a baseline
    calm = runs["calm"]
    assert calm["sched"]["preemptions"] == 0
    for name in ("recompute", "swap"):
        res = runs[name]
        assert len(res["outputs"]) == 6, \
            f"{name}: permanent refusal under over-subscription"
        assert res["outputs"] == calm["outputs"], \
            f"{name}: contended outputs diverged from uncontended"
    if runs["recompute"]["kv_dtype"] == "fp":   # quantized legs widen slots
        assert runs["recompute"]["sched"]["preempts_recompute"] > 0
        assert runs["swap"]["sched"]["preempts_swap"] > 0

    with open("BENCH_serve.json", "w") as f:
        json.dump({"meta": bench_meta("serve"),
                   "geometry": {"page": bs, "slots": n_seq // 2,
                                "blocks_per_seq": nb},
                   "rows": [{"name": n, "us": us, "derived": str(d)}
                            for n, us, d in rows]}, f, indent=2)
    rows.append(("serve/json", 0.0, "BENCH_serve.json"))
    return rows


def bench_spec():
    """Speculative decoding (DESIGN.md §14) → BENCH_spec.json.

    Same row split as bench_serve: the GATED timings are device-free host
    loops (n-gram drafting over a serving-length history, the pool's
    extend→truncate verify-round bookkeeping) plus one jitted XLA verify
    pass — stable on shared runners.  The trace-driven serve rows are
    informational (us=0) and carry acceptance rate and decode tok/s.
    Acceptance criteria are HARD-asserted before the artifact is written:
    spec-on greedy streams are BITWISE equal to spec-off on the fp AND
    int8 pools, speculation reduces decode launches, and on the
    repetitive trace (tiny vocab → greedy decode falls into short token
    cycles the n-gram drafter tracks) k=4 clears >1.5x decode tok/s."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.core.etap import etap_verify_xla
    from repro.launch import serve
    from repro.runtime import spec_decode
    from repro.runtime.paged_cache import BlockPool, layout_for

    rows = []
    # --- gated: host drafter throughput over a serving-length history
    rng = np.random.default_rng(0)
    hist = np.tile(rng.integers(0, 64, size=(64,)), 8)    # cyclic, len 512

    def ngram_x256():
        for off in range(256):
            spec_decode.ngram_propose(hist[: 257 + off], 4)

    rows.append(("spec/ngram_propose_x256", _best_of(ngram_x256),
                 "len<=512 history, k=4"))

    # --- gated: the verify round's pool bookkeeping (extend k -> accept
    # -> truncate the rejected tail in place), the §14 primitive
    bs, nb, slots = 16, 8, 32
    layout = layout_for(slots, nb * bs, block_size=bs)

    def verify_round_x50():
        bp = BlockPool(layout, slots)
        ids = [bp.admit(bs, nb * bs) for _ in range(slots)]
        for i in range(50):
            for s in ids:
                start = int(bp.lengths[s])
                if start + 4 > nb * bs:                   # wrap the window
                    bp.truncate(s, bs, free_blocks=False)
                    start = bs
                bp.extend(s, 4)
                bp.truncate(s, start + 1 + i % 4, free_blocks=False)
        bp.check_conservation()

    rows.append(("spec/verify_round_pool_x50", _best_of(verify_round_x50),
                 f"{slots}slots x 50 extend/truncate rounds"))

    # --- gated: one jitted XLA verify pass (the chunk-shaped launch the
    # serve loop runs per speculation window)
    B, H, Dk, Dv, S, K = 4, 8, 64, 64, 512, 4
    q = jnp.asarray(rng.normal(size=(B, K, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Dv)), jnp.float32)
    qpos = (jnp.asarray([S - K] * B, jnp.int32)[:, None]
            + jnp.arange(K, dtype=jnp.int32)[None, :])
    vfn = jax.jit(lambda: etap_verify_xla(q, k, v, qpos, scale=Dk ** -0.5,
                                          block=64))
    rows.append(("spec/verify_xla_b4_s512_k4", _best_of(vfn),
                 f"B={B} S={S} k={K}"))

    # --- informational + hard asserts: the serve loop on the repetitive
    # trace.  vocab 16 puts greedy decode of the random-weight reduced
    # model into short token cycles within a few dozen tokens — the
    # workload (boilerplate, loops) the §14 target is quoted for.
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None, vocab_size=16)
    base = ["--reduced", "--batch", "4", "--prompt", "16", "--gen", "48",
            "--requests", "4", "--page-size", "8", "--prefill-chunk", "16",
            "--cache-layout", "paged", "--seed", "0"]
    runs = {}
    for name, argv in (("off", []),
                       ("k2", ["--spec-tokens", "2"]),
                       ("k4", ["--spec-tokens", "4"]),
                       ("off_int8", ["--kv-dtype", "int8"]),
                       ("k4_int8", ["--spec-tokens", "4",
                                    "--kv-dtype", "int8"])):
        res = serve.run_paged(serve.parse_args(base + argv), cfg)
        runs[name] = res
        sp = res["spec"] or {}
        rows.append((f"spec/trace/{name}", 0.0,
                     f"tok_s={res['decode_tokens'] / res['t_decode']:.0f};"
                     f"steps={res['steps']};"
                     f"acc={sp.get('acceptance_rate', 0.0):.2f};"
                     f"accepted={sp.get('accepted', 0)};"
                     f"proposed={sp.get('proposed', 0)}"))
    # acceptance, asserted before the artifact can become a baseline
    for on, off in (("k2", "off"), ("k4", "off"), ("k4_int8", "off_int8")):
        assert runs[on]["outputs"] == runs[off]["outputs"], \
            f"{on}: speculative stream diverged from one-at-a-time decode"
    assert runs["k4"]["spec"]["accepted"] > 0, "no drafts accepted at k=4"
    assert runs["k4"]["steps"] < runs["off"]["steps"], \
        "speculation did not reduce decode launches"
    ratio = ((runs["k4"]["decode_tokens"] / runs["k4"]["t_decode"])
             / (runs["off"]["decode_tokens"] / runs["off"]["t_decode"]))
    rows.append(("spec/trace/k4_speedup", 0.0, f"{ratio:.2f}x"))
    assert ratio > 1.5, f"spec decode speedup {ratio:.2f}x <= 1.5x at k=4"

    with open("BENCH_spec.json", "w") as f:
        json.dump({"meta": bench_meta("spec"),
                   "geometry": {"vocab": 16, "batch": 4, "gen": 48,
                                "k": 4, "page": 8},
                   "rows": [{"name": n, "us": us, "derived": str(d)}
                            for n, us, d in rows]}, f, indent=2)
    rows.append(("spec/json", 0.0, "BENCH_spec.json"))
    return rows


def bench_obs():
    """Telemetry overhead (DESIGN.md §15) → BENCH_obs.json.

    Same row split as bench_serve: the GATED timings are the host-side
    telemetry primitives at serving scale (counter incs, log-bucket
    histogram records, trace ring-buffer events, registry snapshot and
    histogram merge) — pure Python, no device dispatch, each sized past
    the 1000us noise floor.  The serve rows are informational (us=0) and
    carry the overhead accounting.  HARD-asserted before the artifact is
    written: a ``--trace-out``/``--metrics-out`` serve run is BITWISE
    output-identical to a plain run on the fp AND int8+prefix-cache legs;
    the trace validates as Chrome trace-event JSON; the metrics file
    round-trips with its schema stamp; and the measured per-op cost times
    the telemetry ops the instrumented run actually performed stays under
    2% of its decode time — the CI budget for always-on telemetry."""
    import dataclasses
    import tempfile

    from repro.configs import get_config, reduced
    from repro.launch import serve
    from repro.runtime import telemetry

    rows = []
    # --- gated: primitive costs at serving scale
    NC, NH = 200_000, 20_000
    reg = telemetry.MetricsRegistry()
    c = reg.counter("bench/ticks")
    h = reg.histogram("bench/lat_ms")
    rng = np.random.default_rng(0)
    vals = np.exp(rng.normal(size=NH)).tolist()

    def inc_xn():
        for _ in range(NC):
            c.inc()

    us = _best_of(inc_xn)
    rows.append((f"obs/counter_inc_x{NC // 1000}k", us,
                 f"{us * 1e3 / NC:.0f}ns/op"))
    inc_ns = us * 1e3 / NC

    def record_xn():
        for v in vals:
            h.record(v)

    us = _best_of(record_xn)
    rows.append((f"obs/hist_record_x{NH // 1000}k", us,
                 f"{us * 1e3 / NH:.0f}ns/op"))
    rec_ns = us * 1e3 / NH

    tr = telemetry.Tracer(capacity=4096)

    def event_xn():
        for i in range(NH):
            tr.instant("tick", tid=i & 7)

    us = _best_of(event_xn)
    rows.append((f"obs/trace_event_x{NH // 1000}k", us,
                 f"{us * 1e3 / NH:.0f}ns/op;cap=4096"))
    evt_ns = us * 1e3 / NH

    full = telemetry.MetricsRegistry()
    for i in range(64):
        full.counter(f"bench/c{i}").inc(i)
    hists = []
    for i in range(8):
        hh = full.histogram(f"bench/h{i}")
        for v in vals[:1000]:
            hh.record(v * (1 + i))
        hists.append(hh)

    def snapshot_x100():
        for _ in range(100):
            full.snapshot()

    rows.append(("obs/snapshot_x100", _best_of(snapshot_x100),
                 "64 counters + 8 hists"))

    def merge_x100():
        for _ in range(100):
            m = hists[0]
            for hh in hists[1:]:
                m = m.merge(hh)

    rows.append(("obs/hist_merge_x100", _best_of(merge_x100),
                 "8-way merge chain, 1k values each"))

    # --- informational + hard asserts: telemetry-on vs -off serve runs
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    base = ["--reduced", "--batch", "2", "--prompt", "16", "--gen", "8",
            "--requests", "3", "--page-size", "8", "--prefill-chunk", "8",
            "--cache-layout", "paged", "--seed", "0"]
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    per_op_ns = max(inc_ns, rec_ns, evt_ns)
    for leg, extra in (("fp", []),
                       ("int8", ["--kv-dtype", "int8",
                                 "--shared-prefix", "2"])):
        plain = serve.run_paged(serve.parse_args(base + extra), cfg)
        tpath = os.path.join(tmp, f"trace_{leg}.json")
        mpath = os.path.join(tmp, f"metrics_{leg}.json")
        inst = serve.run_paged(serve.parse_args(
            base + extra + ["--trace-out", tpath, "--metrics-out", mpath]),
            cfg)
        assert inst["outputs"] == plain["outputs"], \
            f"{leg}: telemetry-on outputs diverged from telemetry-off"
        with open(tpath) as f:
            trace = json.load(f)
        evs = trace["traceEvents"]
        assert evs and all(k in e for e in evs
                           for k in ("ph", "ts", "pid", "tid", "name"))
        names = {e["name"] for e in evs}
        assert {"prefill_chunk", "decode_step"} <= names, names
        with open(mpath) as f:
            met = json.load(f)
        assert met["meta"]["schema_version"] == telemetry.OBS_SCHEMA_VERSION
        snap = met["metrics"]
        assert snap["counters"]["serve/decode_tokens"] \
            == inst["decode_tokens"]
        # analytic overhead: every op the run performed, priced at the
        # WORST measured per-op cost, against its decode wall time
        ops = (sum(snap["counters"].values())
               + sum(hh["count"] for hh in snap["histograms"].values())
               + 8 * snap["counters"].get("serve/ticks", 0)  # gauge sets
               + len(evs))
        frac = ops * per_op_ns * 1e-9 / max(plain["t_decode"], 1e-9)
        assert frac <= 0.02, \
            f"{leg}: modeled telemetry overhead {frac:.2%} > 2% budget"
        rows.append((f"obs/serve/{leg}", 0.0,
                     f"ops={ops};overhead={frac:.3%};"
                     f"tok_s_on={inst['decode_tokens'] / inst['t_decode']:.1f};"
                     f"tok_s_off="
                     f"{plain['decode_tokens'] / plain['t_decode']:.1f}"))

    with open("BENCH_obs.json", "w") as f:
        json.dump({"meta": bench_meta("obs"),
                   "geometry": {"counter_incs": NC, "hist_records": NH,
                                "trace_events": NH},
                   "rows": [{"name": n, "us": us, "derived": str(d)}
                            for n, us, d in rows]}, f, indent=2)
    rows.append(("obs/json", 0.0, "BENCH_obs.json"))
    return rows


def bench_splitkv(full: bool = False):
    """Split-KV ETAP decode sweep → CSV rows + BENCH_splitkv.json."""
    from benchmarks.fig1_throughput import run_splitkv, write_splitkv_json
    rows = run_splitkv(full=full)
    path = write_splitkv_json(rows)
    out = []
    for r in rows:
        out.append((f"splitkv/bs{r['batch']}/s{r['seq']}/n{r['n_splits']}",
                    r["us"],
                    f"{r['gflops']:.2f}GF/s;auto={r['auto_n_splits']};"
                    f"model={r['roofline_t_total_us']:.1f}us"))
    out.append(("splitkv/json", 0.0, path))
    return out


def bench_smoke():
    """CI smoke subset: kernel interpret paths, the paged cache, the
    quantized KV layouts (timings + hard RMSE/capacity asserts), the
    prefix cache, the multi-tenant scheduler (timings + hard bitwise /
    zero-permanent-refusal asserts), speculative decoding (timings + hard
    bitwise / >1.5x-speedup asserts), the telemetry layer (primitive
    timings + hard bitwise-identity / ≤2%-overhead asserts), and a tiny
    split-KV sweep.  Writes BENCH_smoke.json (this aggregate) plus the
    BENCH_paged.json / BENCH_quant.json / BENCH_prefix.json /
    BENCH_serve.json / BENCH_spec.json / BENCH_obs.json /
    BENCH_smoke_splitkv.json the sub-benches emit (the committed
    full-sweep BENCH_splitkv.json is only written by --kv-splits)."""
    rows = []
    rows += bench_kernels_interpret()
    rows += bench_paged()
    rows += bench_quant()
    rows += bench_prefix()
    rows += bench_serve()
    rows += bench_spec()
    rows += bench_obs()
    from benchmarks.fig1_throughput import run_splitkv, write_splitkv_json
    sk = run_splitkv(full=False, splits=(1, 4))
    # own path: never clobber the committed full-sweep BENCH_splitkv.json
    write_splitkv_json(sk, path="BENCH_smoke_splitkv.json")
    for r in sk:
        rows.append((f"splitkv/bs{r['batch']}/s{r['seq']}/n{r['n_splits']}",
                     r["us"], f"{r['gflops']:.2f}GF/s"))
    with open("BENCH_smoke.json", "w") as f:
        json.dump({"meta": bench_meta("smoke"),
                   "rows": [{"name": n, "us": us, "derived": str(d)}
                            for n, us, d in rows]}, f, indent=2)
    rows.append(("smoke/json", 0.0, "BENCH_smoke.json"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kv-splits", action="store_true",
                    help="run the split-KV decode sweep and write "
                         "BENCH_splitkv.json")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset; writes BENCH_smoke.json, "
                         "BENCH_paged.json, BENCH_quant.json, "
                         "BENCH_prefix.json, BENCH_serve.json, "
                         "BENCH_spec.json, BENCH_obs.json and "
                         "BENCH_smoke_splitkv.json")
    ap.add_argument("--full", action="store_true",
                    help="wider sweep geometry")
    ap.add_argument("--rescale", default=os.environ.get("REPRO_RESCALE",
                                                        "amla"),
                    help="online-softmax rescaling mode for every timed "
                         "kernel row: amla (default) | mul")
    args = ap.parse_args(argv)
    from repro.kernels import softmax_state
    softmax_state.set_default_mode(args.rescale)
    if args.smoke:
        benches = [bench_smoke]
    elif args.kv_splits:
        benches = [lambda: bench_splitkv(full=args.full)]
    else:
        benches = [bench_table1_rmse, bench_kernels_interpret,
                   bench_serving_e2e, bench_fig1_throughput]
    print("name,us_per_call,derived")
    for b in benches:
        for name, us, derived in b():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
