"""Roofline table formatter: renders dryrun_results.jsonl (produced by
``python -m repro.launch.dryrun --all --mesh both --out dryrun_results.jsonl``)
as the EXPERIMENTS.md §Roofline markdown table."""
from __future__ import annotations

import argparse
import json


def fmt(rows, mesh_filter=None):
    out = []
    out.append("| arch | shape | mesh | t_compute | t_memory | t_collective |"
               " bottleneck | roofline frac | useful FLOPs | HBM GiB/dev |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        mem = (r.get("argument_size_in_bytes", 0)
               + r.get("temp_size_in_bytes", 0)) / 2 ** 30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']*1e3:.2f} ms | {r['t_memory']*1e3:.2f} ms "
            f"| {r['t_collective']*1e3:.2f} ms | {r['bottleneck']} "
            f"| {r['roofline_fraction']*100:.1f}% "
            f"| {min(r['useful_flops_ratio'], 9.99)*100:.0f}% | {mem:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = [json.loads(l) for l in open(args.json)]
    print(fmt(rows, args.mesh))


if __name__ == "__main__":
    main()
