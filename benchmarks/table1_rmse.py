"""Paper Table 1: numerical-error validation.

RMSE of the half-precision attention output against an FP64 reference
(paper methodology, following FlashAttention-3's study): DeepSeek-R1
geometry (16 heads, dim 576), representative context lengths and batches.
We report ETAP and the standard pipeline in float16 (the paper's dtype)
and bfloat16 (the TPU-native dtype).

Paper's claims to check: FlashMLA-ETAP RMSE ≈ 1.25e-5 in FP16 (15.2x lower
than FA-3's 1.9e-4), i.e. the transposition does NOT degrade numerics.

Usage: PYTHONPATH=src python -m benchmarks.table1_rmse
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.etap import etap_decode_xla, standard_decode_xla
from repro.kernels.etap.ref import etap_decode_ref

HEADS, DIM, DV = 16, 576, 512


def rmse_for(bs: int, s: int, dtype, mode: str, block: int = 512) -> float:
    rng = np.random.default_rng(7)
    # match the FA-3 error study: standard normal Q/K/V
    q64 = rng.normal(size=(bs, HEADS, DIM))
    k64 = rng.normal(size=(bs, s, DIM))
    scale = DIM ** -0.5
    ref = etap_decode_ref(jnp.asarray(q64, jnp.float64),
                          jnp.asarray(k64, jnp.float64),
                          jnp.asarray(k64[..., :DV], jnp.float64),
                          None, scale=scale, dtype=jnp.float64)
    q = jnp.asarray(q64, dtype)
    k = jnp.asarray(k64, dtype)
    v = k[..., :DV]
    fn = etap_decode_xla if mode == "etap" else standard_decode_xla
    out = fn(q, k, v, None, scale=scale, block=block)
    return float(jnp.sqrt(jnp.mean(
        (out.astype(jnp.float64) - ref.astype(jnp.float64)) ** 2)))


def main():
    jax.config.update("jax_enable_x64", True)
    try:
        print(f"{'dtype':>9} {'mode':>9} {'bs':>4} {'seq':>6} {'RMSE':>12}")
        rows = []
        for dtype, name in ((jnp.float16, "float16"), (jnp.bfloat16, "bfloat16")):
            for mode in ("etap", "standard"):
                for bs, s in ((16, 512), (16, 4096), (16, 16384)):
                    r = rmse_for(bs, s, dtype, mode)
                    rows.append((name, mode, bs, s, r))
                    print(f"{name:>9} {mode:>9} {bs:>4} {s:>6} {r:>12.3e}")
        # paper check: fp16 ETAP RMSE in the 1e-5 regime, and ETAP does not
        # degrade numerics vs the standard pipeline
        fp16_etap = [r for n, m, _, _, r in rows if n == "float16" and m == "etap"]
        fp16_std = [r for n, m, _, _, r in rows if n == "float16" and m == "standard"]
        print(f"\nfp16 ETAP mean RMSE    : {np.mean(fp16_etap):.3e} "
              "(paper reports 1.25e-5)")
        print(f"fp16 standard mean RMSE: {np.mean(fp16_std):.3e}")
        print(f"ETAP/standard ratio    : {np.mean(fp16_etap)/np.mean(fp16_std):.2f} "
              "(<=1 means the transposition does not hurt numerics)")
        return rows
    finally:
        jax.config.update("jax_enable_x64", False)


if __name__ == "__main__":
    main()
