#!/usr/bin/env python
"""DEPRECATED shim — this lint is now ``repro.analysis`` rule REPRO006.

The keyword-soup-signature check (no function outside ``core/attn_spec.py``
declaring both ``mode=`` and ``rescale=``) moved into the unified
invariant analyzer (DESIGN.md §16) with the rest of the AST lints.  This
file is kept so local scripts and docs pointing at the old path keep
working; it just runs the analyzer restricted to the ported rule:

    python -m repro.analysis --select REPRO006
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.analysis import cli  # noqa: E402

if __name__ == "__main__":
    print("benchmarks/lint_attn_spec.py is deprecated; running "
          "`python -m repro.analysis --select REPRO006`", file=sys.stderr)
    sys.exit(cli.main(["--select", "REPRO006"]))
