#!/usr/bin/env python
"""AST lint: attention entry points take ONE AttnSpec, not keyword soup.

Before the AttnSpec redesign every attention entry grew the same six
knobs (``mode=``, ``rescale=``, ``kv_splits=``, ...) one keyword at a
time, and call sites drifted — a caller could thread ``mode`` but forget
``rescale`` and silently run a mixed configuration.  The one true bundle
now lives in ``src/repro/core/attn_spec.py``; entry points take
``spec=`` (with a deprecation shim for the old keywords).

This lint fails (exit 1) on any FUNCTION outside that module whose own
parameter list declares BOTH ``mode`` and ``rescale`` — the signature of
a re-introduced pre-AttnSpec entry point.  Either knob alone is fine
(``softmax_state.resolve(rescale)`` helpers take ``rescale``; CLI
builders take ``mode``); both on one signature is an attention entry that
belongs behind the spec.  stdlib-only: runs in the CI lint job before any
heavyweight deps are installed.
"""
from __future__ import annotations

import ast
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SCAN_ROOTS = ("src/repro", "benchmarks")
ALLOWED = {REPO / "src" / "repro" / "core" / "attn_spec.py"}
PAIR = {"mode", "rescale"}


def _param_names(node: ast.FunctionDef | ast.AsyncFunctionDef) -> set:
    a = node.args
    return {p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)}


def _check_file(path: pathlib.Path) -> list[str]:
    tree = ast.parse(path.read_text(), filename=str(path))
    errors = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if PAIR <= _param_names(node):
            rel = (path.relative_to(REPO) if path.is_relative_to(REPO)
                   else path)
            errors.append(
                f"{rel}:{node.lineno}: function `{node.name}` declares "
                f"both `mode=` and `rescale=` — a pre-AttnSpec attention "
                f"entry point; take a single `spec: AttnSpec` instead "
                f"(core/attn_spec.py, DESIGN.md §14)")
    return errors


def main() -> int:
    errors = []
    for root in SCAN_ROOTS:
        for path in sorted((REPO / root).rglob("*.py")):
            if path in ALLOWED:
                continue
            errors.extend(_check_file(path))
    if errors:
        print("\n".join(errors))
        print(f"\nlint_attn_spec: {len(errors)} keyword-soup attention "
              f"entry point(s); the one true bundle is core/attn_spec.py")
        return 1
    print("lint_attn_spec: ok — no mode+rescale signatures outside "
          "attn_spec.py")
    return 0


if __name__ == "__main__":
    sys.exit(main())
