"""Paper Figure 1: decode-attention throughput across context lengths.

The paper's workload: DeepSeek-R1 decode on one GPU-shard — 16 heads,
head dim 576 (the MLA latent), one query token, KV context 512…64K,
batch 16/32, five repeats.

This container has no TPU, so wall-clock numbers are CPU-XLA; what is
preserved from the paper is the *comparison structure*: ETAP (transposed)
vs the standard (FlashMLA-like) pipeline on identical inputs, with derived
attention-FLOPs throughput. The TPU-side performance argument lives in
EXPERIMENTS.md §Roofline/§Perf (lowered-HLO analysis); kernel-level tiling
is validated by tests/test_kernels.py in interpret mode.

Usage: PYTHONPATH=src python -m benchmarks.fig1_throughput [--full]
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.etap import etap_decode_xla, standard_decode_xla

HEADS, DIM, DV = 16, 576, 512   # DeepSeek-R1 decode geometry (paper §4.1)
REPEATS = 5


def attention_flops(bs: int, s: int) -> float:
    # Sᵀ = K·Qᵀ (2·S·D·H) + Oᵀ = Vᵀ·Pᵀ (2·S·Dv·H), per batch row
    return bs * (2.0 * s * DIM * HEADS + 2.0 * s * DV * HEADS)


def bench(fn, q, k, v, block):
    out = fn(q, k, v, None, scale=DIM ** -0.5, block=block)
    jax.block_until_ready(out)           # compile+warm
    ts = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = fn(q, k, v, None, scale=DIM ** -0.5, block=block)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    # min, not mean: scheduler noise on shared CPU runners only ever ADDS
    # time, and the ±20% regression gate (check_regression.py) diffs these
    # rows — the mean let one slow outlier fake a regression.
    return float(np.min(ts))


def run(full: bool = False, block: int = 512):
    seqs = [512, 1024, 2048, 4096, 8192, 16384, 32768, 65536] if full else \
        [512, 1024, 2048, 4096, 8192]
    batches = [16, 32] if full else [16]
    rng = np.random.default_rng(0)
    rows = []
    for bs in batches:
        for s in seqs:
            q = jnp.asarray(rng.normal(size=(bs, HEADS, DIM)), jnp.float32)
            kv = jnp.asarray(rng.normal(size=(bs, s, DIM)), jnp.float32)
            v = kv[..., :DV]
            jit_etap = jax.jit(lambda q, k, v, l, **kw: etap_decode_xla(q, k, v, l, **kw),
                               static_argnames=("scale", "block"))
            jit_std = jax.jit(lambda q, k, v, l, **kw: standard_decode_xla(q, k, v, l, **kw),
                              static_argnames=("scale", "block"))
            t_etap = bench(jit_etap, q, kv, v, block)
            t_std = bench(jit_std, q, kv, v, block)
            fl = attention_flops(bs, s)
            rows.append(dict(batch=bs, seq=s,
                             etap_us=t_etap * 1e6, std_us=t_std * 1e6,
                             etap_gflops=fl / t_etap / 1e9,
                             std_gflops=fl / t_std / 1e9,
                             speedup=t_std / t_etap))
    return rows


def run_splitkv(full: bool = False, block: int = 512,
                splits=(1, 2, 4, 8)):
    """Split-KV sweep: two-phase ETAP decode (XLA split path — the same
    partial/combine math the Pallas kernels run) across split counts, at the
    small-batch × long-context geometry the tile scheduler targets. Each row
    also records what the auto-scheduler would pick and the modeled TPU
    roofline time for that split count."""
    from repro.core.etap import etap_decode_splitkv_xla
    from repro.kernels.etap.schedule import plan_splits
    from repro.launch.roofline import splitkv_roofline

    seqs = [4096, 16384, 32768] if full else [2048, 8192]
    batches = [1, 8] if full else [1, 4]
    rng = np.random.default_rng(0)
    rows = []
    for bs in batches:
        for s in seqs:
            q = jnp.asarray(rng.normal(size=(bs, HEADS, DIM)), jnp.float32)
            kv = jnp.asarray(rng.normal(size=(bs, s, DIM)), jnp.float32)
            v = kv[..., :DV]
            auto = plan_splits(bs, s, HEADS, DV, block=block).n_splits
            for n in splits:
                fn = jax.jit(functools.partial(
                    etap_decode_splitkv_xla, scale=DIM ** -0.5,
                    block=block, n_splits=n))
                t = bench(lambda q, k, v, l, **_: fn(q, k, v, l), q, kv, v,
                          block)
                fl = attention_flops(bs, s)
                # mla_fused=False: the measured XLA path streams separate
                # K and V arrays, so the model must account Dk+Dv bytes.
                rl = splitkv_roofline(bs, s, HEADS, DIM, DV, n,
                                      mla_fused=False)
                rows.append(dict(
                    batch=bs, seq=s, n_splits=n, us=t * 1e6,
                    gflops=fl / t / 1e9, auto_n_splits=auto,
                    roofline_t_total_us=rl["t_total"] * 1e6,
                    roofline_overhead=rl["overhead"],
                    roofline_occupancy=rl["occupancy"]))
    return rows


def write_splitkv_json(rows, path: str = "BENCH_splitkv.json"):
    import json

    from benchmarks.run import bench_meta
    with open(path, "w") as f:
        json.dump({"meta": bench_meta(path.rsplit(".", 1)[0]),
                   "geometry": {"heads": HEADS, "dim": DIM, "dv": DV},
                   "rows": rows}, f, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper's full sweep (512…64K, bs 16+32)")
    ap.add_argument("--kv-splits", action="store_true",
                    help="run the split-KV sweep and write BENCH_splitkv.json")
    args = ap.parse_args()
    if args.kv_splits:
        rows = run_splitkv(full=args.full)
        path = write_splitkv_json(rows)
        print(f"{'bs':>4} {'seq':>7} {'splits':>6} {'us':>12} {'GF/s':>10} "
              f"{'auto':>5} {'model us':>10}")
        for r in rows:
            print(f"{r['batch']:>4} {r['seq']:>7} {r['n_splits']:>6} "
                  f"{r['us']:>12.0f} {r['gflops']:>10.2f} "
                  f"{r['auto_n_splits']:>5} {r['roofline_t_total_us']:>10.1f}")
        print(f"wrote {path}")
        return rows
    rows = run(full=args.full)
    print(f"{'bs':>4} {'seq':>7} {'ETAP us':>12} {'std us':>12} "
          f"{'ETAP GF/s':>10} {'std GF/s':>10} {'speedup':>8}")
    for r in rows:
        print(f"{r['batch']:>4} {r['seq']:>7} {r['etap_us']:>12.0f} "
              f"{r['std_us']:>12.0f} {r['etap_gflops']:>10.2f} "
              f"{r['std_gflops']:>10.2f} {r['speedup']:>8.2f}")
    return rows


if __name__ == "__main__":
    main()
