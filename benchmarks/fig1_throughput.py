"""Paper Figure 1: decode-attention throughput across context lengths.

The paper's workload: DeepSeek-R1 decode on one GPU-shard — 16 heads,
head dim 576 (the MLA latent), one query token, KV context 512…64K,
batch 16/32, five repeats.

This container has no TPU, so wall-clock numbers are CPU-XLA; what is
preserved from the paper is the *comparison structure*: ETAP (transposed)
vs the standard (FlashMLA-like) pipeline on identical inputs, with derived
attention-FLOPs throughput. The TPU-side performance argument lives in
EXPERIMENTS.md §Roofline/§Perf (lowered-HLO analysis); kernel-level tiling
is validated by tests/test_kernels.py in interpret mode.

Usage: PYTHONPATH=src python -m benchmarks.fig1_throughput [--full]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.etap import etap_decode_xla, standard_decode_xla

HEADS, DIM, DV = 16, 576, 512   # DeepSeek-R1 decode geometry (paper §4.1)
REPEATS = 5


def attention_flops(bs: int, s: int) -> float:
    # Sᵀ = K·Qᵀ (2·S·D·H) + Oᵀ = Vᵀ·Pᵀ (2·S·Dv·H), per batch row
    return bs * (2.0 * s * DIM * HEADS + 2.0 * s * DV * HEADS)


def bench(fn, q, k, v, block):
    out = fn(q, k, v, None, scale=DIM ** -0.5, block=block)
    jax.block_until_ready(out)           # compile+warm
    ts = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = fn(q, k, v, None, scale=DIM ** -0.5, block=block)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts))


def run(full: bool = False, block: int = 512):
    seqs = [512, 1024, 2048, 4096, 8192, 16384, 32768, 65536] if full else \
        [512, 1024, 2048, 4096, 8192]
    batches = [16, 32] if full else [16]
    rng = np.random.default_rng(0)
    rows = []
    for bs in batches:
        for s in seqs:
            q = jnp.asarray(rng.normal(size=(bs, HEADS, DIM)), jnp.float32)
            kv = jnp.asarray(rng.normal(size=(bs, s, DIM)), jnp.float32)
            v = kv[..., :DV]
            jit_etap = jax.jit(lambda q, k, v, l, **kw: etap_decode_xla(q, k, v, l, **kw),
                               static_argnames=("scale", "block"))
            jit_std = jax.jit(lambda q, k, v, l, **kw: standard_decode_xla(q, k, v, l, **kw),
                              static_argnames=("scale", "block"))
            t_etap = bench(jit_etap, q, kv, v, block)
            t_std = bench(jit_std, q, kv, v, block)
            fl = attention_flops(bs, s)
            rows.append(dict(batch=bs, seq=s,
                             etap_us=t_etap * 1e6, std_us=t_std * 1e6,
                             etap_gflops=fl / t_etap / 1e9,
                             std_gflops=fl / t_std / 1e9,
                             speedup=t_std / t_etap))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper's full sweep (512…64K, bs 16+32)")
    args = ap.parse_args()
    rows = run(full=args.full)
    print(f"{'bs':>4} {'seq':>7} {'ETAP us':>12} {'std us':>12} "
          f"{'ETAP GF/s':>10} {'std GF/s':>10} {'speedup':>8}")
    for r in rows:
        print(f"{r['batch']:>4} {r['seq']:>7} {r['etap_us']:>12.0f} "
              f"{r['std_us']:>12.0f} {r['etap_gflops']:>10.2f} "
              f"{r['std_gflops']:>10.2f} {r['speedup']:>8.2f}")
    return rows


if __name__ == "__main__":
    main()
