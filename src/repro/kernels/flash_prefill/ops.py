"""jit'd wrapper for the causal flash prefill kernel (AttnSpec entry; the
bq/bkv tile sizes stay explicit static keywords — they are kernel tiling
knobs, not attention semantics)."""
from __future__ import annotations

from repro.core import attn_spec
from repro.kernels.flash_prefill.flash_prefill import flash_prefill_pallas


@attn_spec.attn_entry(uses=("interpret", "rescale"),
                      static_argnames=("bq", "bkv"))
def flash_prefill(q, k, v, *, spec, bq: int = 256, bkv: int = 256):
    return flash_prefill_pallas(q, k, v, scale=spec.scale, bq=bq, bkv=bkv,
                                interpret=spec.interpret,
                                rescale=spec.rescale)
