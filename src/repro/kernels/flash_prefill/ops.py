"""jit'd wrapper for the causal flash prefill kernel."""
from __future__ import annotations

from repro.kernels import softmax_state
from repro.kernels.flash_prefill.flash_prefill import flash_prefill_pallas


@softmax_state.jit_with_rescale(
    static_argnames=("scale", "bq", "bkv", "interpret"))
def flash_prefill(q, k, v, *, scale: float, bq: int = 256, bkv: int = 256,
                  interpret: bool = True, rescale: str | None = None):
    return flash_prefill_pallas(q, k, v, scale=scale, bq=bq, bkv=bkv,
                                interpret=interpret, rescale=rescale)
