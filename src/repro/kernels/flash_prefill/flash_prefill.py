"""Blockwise causal flash-attention prefill kernel (beyond-paper).

The XLA train/prefill path (models.attention.causal_attention) pays masked
upper-triangle FLOPs; this kernel skips fully-masked KV blocks via pl.when
AND pins the index_map to min(i, j) so skipped steps do not stream KV from
HBM. GQA is handled by mapping the kv-head block index to bh // group.

Grid: (B*H, S/Bq, S/Bkv) — kv fastest (serial, online softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import softmax_state

NEG_INF = softmax_state.NEG_INF


def _body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
          *, scale: float, bq: int, bkv: int, nkv: int, rescale: str):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        softmax_state.init_refs(m_ref, l_ref, acc_ref)

    # causal block skip in POSITION terms (bq and bkv may differ: kv block j
    # is needed iff its first row j·bkv precedes the q block's last row)
    @pl.when(j * bkv <= i * bq + bq - 1)
    def _compute():
        q = q_ref[0]                                   # [bq, D]
        k = k_ref[0]                                   # [bkv, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # in-block causal mask (only the diagonal block is partially masked,
        # but the branchless form costs nothing on the VPU)
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

        v_blk = v_ref[0]
        m_ref[...], l_ref[...], acc_ref[...] = softmax_state.update(
            (m_ref[...], l_ref[...], acc_ref[...]), s,
            lambda p: jax.lax.dot_general(
                p, v_blk, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32),
            axis=1, mode=rescale)

    @pl.when(j == nkv - 1)
    def _epilogue():
        o_ref[0] = softmax_state.finalize(
            (None, l_ref[...], acc_ref[...])).astype(o_ref.dtype)


def flash_prefill_pallas(q, k, v, *, scale: float, bq: int = 256,
                         bkv: int = 256, interpret: bool = True,
                         rescale: str | None = None):
    """q: [B,S,H,D]; k,v: [B,S,K,D*] (GQA) -> [B,S,H,Dv]."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    Dv = v.shape[-1]
    bq, bkv = min(bq, S), min(bkv, S)
    assert S % bq == 0 and S % bkv == 0
    nq, nkv = S // bq, S // bkv

    qh = jnp.swapaxes(q, 1, 2).reshape(B * H, S, D)
    kh = jnp.swapaxes(k, 1, 2).reshape(B * K, S, D)
    vh = jnp.swapaxes(v, 1, 2).reshape(B * K, S, Dv)

    out = pl.pallas_call(
        functools.partial(_body, scale=scale, bq=bq, bkv=bkv, nkv=nkv,
                          rescale=softmax_state.resolve(rescale)),
        grid=(B * H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, i, j: (bh, i, 0)),
            # skipped steps re-point at the last needed kv block: no extra
            # HBM traffic (last needed j for q block i = (i·bq+bq-1)//bkv)
            pl.BlockSpec((1, bkv, D),
                         lambda bh, i, j, G=G: (
                             bh // G,
                             jnp.minimum(j, (i * bq + bq - 1) // bkv), 0)),
            pl.BlockSpec((1, bkv, Dv),
                         lambda bh, i, j, G=G: (
                             bh // G,
                             jnp.minimum(j, (i * bq + bq - 1) // bkv), 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda bh, i, j: (bh, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dv), v.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.swapaxes(out.reshape(B, H, S, Dv), 1, 2)
