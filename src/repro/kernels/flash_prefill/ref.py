"""Pure-jnp oracle for the causal prefill kernel (direct masked softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_attention_ref(q, k, v, *, scale: float, dtype=jnp.float32):
    """q: [B,S,H,D]; k,v: [B,S,K,D*] -> [B,S,H,Dv] (GQA by head grouping)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.reshape(B, S, K, G, D).astype(dtype)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qf, k.astype(dtype)) * dtype(scale)
    mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
    s = jnp.where(mask[None, :, None, None, :], s, dtype(-jnp.inf))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskv->bqkgv", p, v.astype(dtype))
    return o.reshape(B, S, H, v.shape[-1]).astype(v.dtype)
