"""THE online-softmax state API (DESIGN.md §13).

Every online-softmax hot loop in the repo — single-pass ETAP decode, the
split-KV partials, chunked prefill, the flash_decode/flash_prefill
baselines, the XLA twins in ``core/etap.py``, and both combine backends —
carries its state as one fp32 triple ``(m, l, acc)`` and advances/merges it
EXCLUSIVELY through this module (``benchmarks/lint_softmax.py`` rejects any
new hand-rolled rescale chain outside this file).  The functions are plain
``jnp`` math on values, so they inline into Pallas kernel bodies and trace
under XLA from the SAME definition — kernel and reference cannot drift.

Two flag-selectable rescale modes (``--rescale {mul,amla}``):

``mul``  — the textbook FlashAttention recurrence.  ``m`` is the running
  score max (natural-log domain); each block multiplies ``l``/``acc`` by
  ``corr = exp(m_old - m_new)``, an inexact transcendental that injects
  rounding into the accumulator at every max motion.

``amla`` (default) — AMLA-style deferred rescaling ("MUL by ADD in
  FlashAttention Rescaling", PAPERS.md).  ``m`` holds a power-of-two
  running bias ``b = ceil(log2 e · max score)`` — an INTEGER-valued fp32 —
  and probabilities are ``p = exp2(score·log2e − b)``.  Because ``b`` only
  moves in integer steps, ``corr = 2^(b_old − b_new)`` is an exact power of
  two: the accumulator rescale is an exponent-field addition in disguise,
  EXACT in floating point (and the exact multiply-by-one no-op for every
  block that doesn't raise the ceiling — most of them).  The rescale chain
  stops being a rounding source entirely; only the ``p``/``l`` additions
  round, same as ``mul``.  On the WGMMA-adjacent epilogue path the paper
  identifies as the M-dimension bottleneck this also replaces the FMA
  rescale traffic with exponent adds.

The state domain differs between modes (natural-log max vs log2 bias), so
partial stats must be merged in the mode that produced them — every
producer/consumer pair below threads one ``rescale`` value end to end.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

NEG_INF = -1e30
LOG2E = 1.4426950408889634  # log2(e); exactly representable rounding of it

MODES = ("mul", "amla")

_DEFAULT_MODE = [os.environ.get("REPRO_RESCALE", "amla")]


def _check(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"rescale mode {mode!r} not in {MODES}")
    return mode


def default_mode() -> str:
    """The process-wide rescale mode (env ``REPRO_RESCALE``, default amla)."""
    return _check(_DEFAULT_MODE[0])


def set_default_mode(mode: str) -> None:
    """Set the process-wide mode (the serve/bench ``--rescale`` flag).  Must
    run before the first trace of any consumer — jitted entry points bake
    the resolved mode into their cache key via :func:`jit_with_rescale`, but
    closures already traced with the old default are not retraced."""
    _DEFAULT_MODE[0] = _check(mode)


def resolve(mode: str | None = None) -> str:
    """None → the process default; anything else is validated and passed
    through.  Every public entry point resolves exactly once, at the top."""
    return default_mode() if mode is None else _check(mode)


def jit_with_rescale(*, static_argnames=()):
    """``jax.jit`` for kernel entry points carrying a ``rescale`` kwarg:
    ``rescale=None`` is resolved to the process default BEFORE the jit cache
    is consulted, so flipping the default between calls can never serve a
    stale trace (a plain static ``None`` default would)."""
    def deco(fn):
        jfn = jax.jit(fn,
                      static_argnames=tuple(static_argnames) + ("rescale",))

        @functools.wraps(fn)
        def wrapper(*args, rescale=None, **kw):
            return jfn(*args, rescale=resolve(rescale), **kw)
        wrapper.__wrapped_jit__ = jfn
        return wrapper
    return deco


def _identity(x):
    return x


def _exp(mode: str):
    return jnp.exp2 if mode == "amla" else jnp.exp


# ------------------------------------------------------------------ state
def init(stats_shape, acc_shape, dtype=jnp.float32):
    """Fresh ``(m, l, acc)`` — fp32 by contract (DESIGN.md §6/§11)."""
    return (jnp.full(stats_shape, NEG_INF, dtype),
            jnp.zeros(stats_shape, dtype),
            jnp.zeros(acc_shape, dtype))


def init_refs(m_ref, l_ref, acc_ref) -> None:
    """Pallas form of :func:`init`: reset the VMEM scratch refs in place."""
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def update(state, s, pv, *, axis: int, mode: str, expand=_identity):
    """One online-softmax block update.

    ``s``: fp32 score block, already scaled and masked (``NEG_INF``).
    ``pv``: the caller's probability-value contraction ``p -> ΔAcc`` (the
    one thing that differs per kernel orientation); ``p`` is fp32 with
    ``s``'s shape.  ``axis``: the KV (reduction) axis of ``s``.  ``expand``
    broadcasts a stats-shaped array against ``acc`` (identity when the
    stats keep the reduced axis as size 1, as in the Pallas tile layouts).

    Stats keep ``s``'s rank iff the incoming ``m`` does (Pallas keeps the
    reduced axis; the XLA loops drop it) — the update follows suit, so both
    forms share this single definition.
    """
    m, l, acc = state
    keep = (jnp.ndim(s) == jnp.ndim(m))
    if mode == "amla":
        s = s * LOG2E                       # log2 domain
        block_m = jnp.ceil(jnp.max(s, axis=axis, keepdims=keep))
    else:
        block_m = jnp.max(s, axis=axis, keepdims=keep)
    exp_fn = _exp(mode)
    m_new = jnp.maximum(m, block_m)
    p = exp_fn(s - (m_new if keep else jnp.expand_dims(m_new, axis)))
    corr = exp_fn(m - m_new)                # amla: exact power of two
    l_new = l * corr + jnp.sum(p, axis=axis, keepdims=keep)
    acc_new = acc * expand(corr) + pv(p)
    return m_new, l_new, acc_new


def finalize(state, *, expand=_identity):
    """Epilogue: ``acc / l`` (the running bias cancels in both modes).
    Orientation transposes and the output cast stay with the caller."""
    _, l, acc = state
    return acc / expand(l)


# ------------------------------------------------------------------ merge
def merge_splits(m, l, acc, *, axis: int, mode: str, expand=_identity):
    """Merge per-split stats along ``axis`` in the stat domain — one global
    rescale per split, never a renormalize-then-renormalize chain:

        m* = max_s m_s        w_s = expΔ(m_s − m*)     (amla: exact 2^Δ)
        l* = Σ_s w_s l_s      acc* = Σ_s w_s acc_s

    A fully-masked split carries ``(m = NEG_INF, l = 0)``; its weight
    underflows to exactly 0 and it drops out without a branch.  With a
    single split the weights are expΔ(0) = 1 and the merge is bitwise the
    identity — the n_splits=1 ↔ single-pass contract rides on this.

    The fp32-on-entry upcast lives HERE and nowhere else (the PR 5
    bf16-combine-stats guard): callers may hand half-precision stats, the
    merge math is fp32 regardless; only the caller's final output cast may
    be narrow.  Returns merged ``(m, l, acc)`` with ``axis`` reduced.
    """
    m = m.astype(jnp.float32)
    l = l.astype(jnp.float32)
    acc = acc.astype(jnp.float32)
    m_g = jnp.max(m, axis=axis, keepdims=True)
    w = _exp(mode)(m - m_g)
    l_g = jnp.sum(l * w, axis=axis)
    acc_g = jnp.sum(acc * expand(w), axis=axis)
    return jnp.squeeze(m_g, axis=axis), l_g, acc_g


def merge(a, b, *, mode: str, expand=_identity):
    """Pairwise stat-domain merge of two states (same math as
    :func:`merge_splits` over a 2-long axis).  Bitwise commutative in both
    modes; in amla mode the weights are exact powers of two, so on exact-
    addition data ANY merge tree finalizes bitwise equal (the property
    tests pin this).  Upcasts on entry like every merge."""
    ma, la, acca = (x.astype(jnp.float32) for x in a)
    mb, lb, accb = (x.astype(jnp.float32) for x in b)
    exp_fn = _exp(mode)
    m = jnp.maximum(ma, mb)
    wa = exp_fn(ma - m)
    wb = exp_fn(mb - m)
    return (m, la * wa + lb * wb, acca * expand(wa) + accb * expand(wb))


def merge_weights(m, m_global, *, mode: str):
    """Per-shard combine weight ``w = expΔ(m − m*)`` for the cross-device
    (pmax/psum) combine — the shard_map twin of :func:`merge_splits`, where
    the Σ is an all-reduce the caller owns.  fp32 on entry, like every
    merge."""
    return _exp(mode)(m.astype(jnp.float32) - m_global.astype(jnp.float32))
