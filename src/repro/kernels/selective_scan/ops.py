"""jit'd wrapper for the selective-scan kernel (pads L and D to blocks)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.selective_scan.scan import selective_scan_pallas


@functools.partial(jax.jit,
                   static_argnames=("chunk", "d_block", "interpret"))
def selective_scan(dA, dBx, c, *, chunk: int = 256, d_block: int = 256,
                   interpret: bool = True):
    """dA, dBx: [B,L,D,N]; c: [B,L,N] -> y [B,L,D] (f32).
    Pads L (zero dA/dBx rows keep the padded steps inert: h := 0·h + 0)
    and D to block multiples; slices the result back."""
    B, L, D, N = dA.shape
    chunk = min(chunk, L)
    d_block = min(d_block, D)
    padL = (-L) % chunk
    padD = (-D) % d_block
    if padL or padD:
        dA = jnp.pad(dA, ((0, 0), (0, padL), (0, padD), (0, 0)))
        dBx = jnp.pad(dBx, ((0, 0), (0, padL), (0, padD), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, padL), (0, 0)))
    y, h = selective_scan_pallas(dA.astype(jnp.float32),
                                 dBx.astype(jnp.float32),
                                 c.astype(jnp.float32),
                                 chunk=chunk, d_block=d_block,
                                 interpret=interpret)
    # padded steps have dA=dBx=0, so h after padding is 0 — but the final
    # state must be the one at step L: with right-padding dA=0 zeroes it.
    # ops therefore only exposes h when L % chunk == 0 (no padding).
    return (y[:, :L, :D], h[:, :D] if not padL else None)
