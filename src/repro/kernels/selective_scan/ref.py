"""Pure-jnp oracle for the selective scan: direct sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dA, dBx, c, dtype=jnp.float32):
    """dA, dBx: [B,L,D,N]; c: [B,L,N] -> y [B,L,D]; computed in `dtype`."""
    dA = dA.astype(dtype)
    dBx = dBx.astype(dtype)
    c = c.astype(dtype)

    def step(h, xs):
        a, b, ct = xs
        h = a * h + b                                   # [B,D,N]
        return h, jnp.einsum("bdn,bn->bd", h, ct)

    B, L, D, N = dA.shape
    h0 = jnp.zeros((B, D, N), dtype)
    _, y = jax.lax.scan(step, h0, (jnp.swapaxes(dA, 0, 1),
                                   jnp.swapaxes(dBx, 0, 1),
                                   jnp.swapaxes(c, 0, 1)))
    return jnp.swapaxes(y, 0, 1)
