"""Pallas TPU selective-scan (Mamba-1) forward kernel.

The XLA associative-scan path moves O(log L) full-size state temporaries
through HBM (EXPERIMENTS.md §Perf-M); the CUDA mamba kernel keeps the
recurrence state in SRAM. The TPU-native translation: grid over
(batch, d_inner blocks, sequence chunks) with the chunk dimension serial —
the [d_blk, N] state lives in a VMEM scratch across chunk steps, dA/dBx/C
stream through VMEM once, y is written once. HBM traffic = one read of the
inputs + one write of y (the paper-style "state never leaves fast memory"
property, adapted from SRAM/warp terms to VMEM/grid terms).

    h_t = dA_t ⊙ h_{t-1} + dBx_t          dA, dBx: [B, L, D, N]
    y_t = Σ_n C_{t,n} · h_{t,d,n}         C: [B, L, N] → y: [B, L, D]

The in-chunk loop is a jax.lax.fori_loop over time INSIDE the kernel body —
steps are [d_blk, N] VPU ops with no HBM round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _body(dA_ref, dBx_ref, c_ref, y_ref, hout_ref, h_ref, *, chunk: int,
          nchunks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dA = dA_ref[0]          # [chunk, d_blk, N]
    dBx = dBx_ref[0]
    c = c_ref[0]            # [chunk, N]

    def step(t, h):
        h = dA[t] * h + dBx[t]                          # [d_blk, N]
        y_ref[0, t, :] = jnp.sum(h * c[t][None, :], axis=1)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(j == nchunks - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


def selective_scan_pallas(dA, dBx, c, *, chunk: int = 256,
                          d_block: int = 256, interpret: bool = True):
    """dA, dBx: [B, L, D, N] f32; c: [B, L, N] f32 -> y: [B, L, D] f32.
    L % chunk == 0 and D % d_block == 0 required (ops.py pads)."""
    B, L, D, N = dA.shape
    chunk = min(chunk, L)
    d_block = min(d_block, D)
    assert L % chunk == 0 and D % d_block == 0
    grid = (B, D // d_block, L // chunk)

    return pl.pallas_call(
        functools.partial(_body, chunk=chunk, nchunks=L // chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d_block, N),
                         lambda b, d, j: (b, j, d, 0)),
            pl.BlockSpec((1, chunk, d_block, N),
                         lambda b, d, j: (b, j, d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, d_block, N), lambda b, d, j: (b, d, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, L, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, D, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d_block, N), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dA, dBx, c)
