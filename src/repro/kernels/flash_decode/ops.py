"""jit'd wrappers for the baseline (untransposed) flash decode kernel:
single-pass and split-KV two-phase entry points."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import softmax_state
from repro.kernels.etap.combine import combine_splits
from repro.kernels.etap.schedule import plan_splits, split_geometry
from repro.kernels.flash_decode.flash_decode import (
    flash_decode_pallas, flash_decode_partial_pallas)


@softmax_state.jit_with_rescale(
    static_argnames=("scale", "block", "interpret"))
def flash_decode(q, k, v, length=None, *, scale: float, block: int = 512,
                 interpret: bool = True, rescale: str | None = None):
    BG = q.shape[0]
    S = k.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    pad = (-S) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    return flash_decode_pallas(q, k, v, length, scale=scale, block=block,
                               interpret=interpret, rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("scale", "block", "n_splits", "combine", "interpret"))
def flash_decode_splitkv(q, k, v, length=None, *, scale: float,
                         block: int = 512, n_splits: int = 0,
                         combine: str = "pallas", interpret: bool = True,
                         rescale: str | None = None):
    """Two-phase split-KV baseline decode (same scheduler as the ETAP path;
    n_splits = 0 → auto, 1 → single-pass, bit-identical — see
    kernels/etap/combine.py)."""
    BG, H, _ = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    if not n_splits:
        n_splits = plan_splits(BG, S, H, Dv, block=block).n_splits
    if n_splits <= 1:
        return flash_decode(q, k, v, length, scale=scale, block=block,
                            interpret=interpret, rescale=rescale)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    # effective split count from the shared geometry (clamped so every
    # split owns >= 1 real KV block — short contexts degrade to fewer)
    block, n_splits, _, target = split_geometry(S, block, n_splits)
    if n_splits <= 1:
        return flash_decode(q, k, v, length, scale=scale, block=block,
                            interpret=interpret, rescale=rescale)
    pad = target - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    m, l, acc = flash_decode_partial_pallas(q, k, v, length, scale=scale,
                                            block=block, n_splits=n_splits,
                                            interpret=interpret,
                                            rescale=rescale)
    return combine_splits(m, l, acc, transposed=False, out_dtype=v.dtype,
                          combine=combine, interpret=interpret,
                          rescale=rescale)
