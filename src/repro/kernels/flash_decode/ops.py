"""jit'd wrapper for the baseline (untransposed) flash decode kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import flash_decode_pallas


@functools.partial(jax.jit, static_argnames=("scale", "block", "interpret"))
def flash_decode(q, k, v, length=None, *, scale: float, block: int = 512,
                 interpret: bool = True):
    BG = q.shape[0]
    S = k.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    pad = (-S) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    return flash_decode_pallas(q, k, v, length, scale=scale, block=block,
                               interpret=interpret)
