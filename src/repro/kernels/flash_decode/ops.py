"""jit'd wrappers for the baseline (untransposed) flash decode kernel:
single-pass and split-KV two-phase entry points.  Entry points take one
:class:`repro.core.attn_spec.AttnSpec` (legacy keywords shim through with
a DeprecationWarning — see attn_spec.attn_entry)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import attn_spec
from repro.kernels.etap.combine import combine_splits
from repro.kernels.etap.schedule import plan_splits, split_geometry
from repro.kernels.flash_decode.flash_decode import (
    flash_decode_pallas, flash_decode_partial_pallas)


@attn_spec.attn_entry(uses=("block", "interpret", "rescale"))
def flash_decode(q, k, v, length=None, *, spec):
    BG = q.shape[0]
    S = k.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(spec.block, S)
    pad = (-S) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    return flash_decode_pallas(q, k, v, length, scale=spec.scale,
                               block=block, interpret=spec.interpret,
                               rescale=spec.rescale)


@attn_spec.attn_entry(uses=("block", "kv_splits", "interpret", "rescale"),
                      static_argnames=("combine",))
def flash_decode_splitkv(q, k, v, length=None, *, spec,
                         combine: str = "pallas"):
    """Two-phase split-KV baseline decode (same scheduler as the ETAP path;
    spec.kv_splits None/0 → auto, 1 → single-pass, bit-identical — see
    kernels/etap/combine.py)."""
    BG, H, _ = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    n_splits = int(spec.kv_splits or 0)
    if not n_splits:
        n_splits = plan_splits(BG, S, H, Dv, block=spec.block).n_splits
    if n_splits <= 1:
        return flash_decode(q, k, v, length, spec=spec)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    # effective split count from the shared geometry (clamped so every
    # split owns >= 1 real KV block — short contexts degrade to fewer)
    block, n_splits, _, target = split_geometry(S, spec.block, n_splits)
    if n_splits <= 1:
        return flash_decode(q, k, v, length,
                            spec=spec.replace(block=block))
    pad = target - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    m, l, acc = flash_decode_partial_pallas(q, k, v, length,
                                            scale=spec.scale,
                                            block=block, n_splits=n_splits,
                                            interpret=spec.interpret,
                                            rescale=spec.rescale)
    return combine_splits(m, l, acc, transposed=False, out_dtype=v.dtype,
                          combine=combine, interpret=spec.interpret,
                          rescale=spec.rescale)
