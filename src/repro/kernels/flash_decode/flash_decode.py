"""Baseline flash decode-attention kernel — the *untransposed* pipeline
(FlashMLA-without-ETAP). Identical tiling/pipelining to the ETAP kernel so
the two differ ONLY in computation orientation:

    S_j = Q Kᵀ_j     [H, B_kv]    (thin head dim on the GEMM M dimension)
    m, ℓ : per-ROW online stats   [H, 1]
    Acc += P_j V_j   [H, Dv]

This is the comparison target for the paper's Figure-1 claim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import softmax_state

NEG_INF = softmax_state.NEG_INF


def _body(length_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
          *, scale: float, block: int, nb: int, rescale: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        softmax_state.init_refs(m_ref, l_ref, acc_ref)

    q = q_ref[0]                                        # [H, Dk]
    k_blk = k_ref[0]                                    # [block, Dk]
    s = jax.lax.dot_general(
        q, k_blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [H, block]

    length = length_ref[pl.program_id(0)]
    pos = j * block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    v_blk = v_ref[0]
    m_ref[...], l_ref[...], acc_ref[...] = softmax_state.update(
        (m_ref[...], l_ref[...], acc_ref[...]), s,
        lambda p: jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),        # [H, Dv]
        axis=1, mode=rescale)

    @pl.when(j == nb - 1)
    def _epilogue():
        o_ref[0] = softmax_state.finalize(
            (None, l_ref[...], acc_ref[...])).astype(o_ref.dtype)


def flash_decode_pallas(q, k, v, length, *, scale: float, block: int = 512,
                        interpret: bool = True, rescale: str | None = None):
    """q: [BG,H,Dk]; k: [BG,S,Dk]; v: [BG,S,Dv]; length: [BG]. -> [BG,H,Dv]."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    block = min(block, S)
    assert S % block == 0
    nb = S // block

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BG, nb),
        in_specs=[
            pl.BlockSpec((1, H, Dk), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, block, Dk), lambda b, j, *_: (b, j, 0)),
            pl.BlockSpec((1, block, Dv), lambda b, j, *_: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, Dv), lambda b, j, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, Dv), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_body, scale=scale, block=block, nb=nb,
                          rescale=softmax_state.resolve(rescale)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BG, H, Dv), v.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(length.astype(jnp.int32), q, k, v)


# ------------------------------------------------------- split-KV (phase 1)
def _partial_body(length_ref, q_ref, k_ref, v_ref,
                  m_out_ref, l_out_ref, acc_out_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, block: int,
                  npb: int, rescale: str):
    """Split-KV partial for the untransposed baseline: 3-D
    ``(BG, n_splits, nb_per_split)`` grid emitting per-split (m, ℓ, Acc)
    stats in the standard [H, ·] orientation (merged by
    ``kernels.etap.combine`` with transposed=False)."""
    s = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        softmax_state.init_refs(m_ref, l_ref, acc_ref)

    q = q_ref[0]                                        # [H, Dk]
    k_blk = k_ref[0]                                    # [block, Dk]
    sc = jax.lax.dot_general(
        q, k_blk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [H, block]

    length = length_ref[pl.program_id(0)]
    pos = (s * npb + j) * block + jax.lax.broadcasted_iota(
        jnp.int32, sc.shape, 1)
    sc = jnp.where(pos < length, sc, NEG_INF)

    v_blk = v_ref[0]
    m_ref[...], l_ref[...], acc_ref[...] = softmax_state.update(
        (m_ref[...], l_ref[...], acc_ref[...]), sc,
        lambda p: jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),        # [H, Dv]
        axis=1, mode=rescale)

    @pl.when(j == npb - 1)
    def _emit():
        m_out_ref[0] = m_ref[...].T                     # [1, H]
        l_out_ref[0] = l_ref[...].T
        acc_out_ref[0, 0] = acc_ref[...]


def flash_decode_partial_pallas(q, k, v, length, *, scale: float, block: int,
                                n_splits: int, interpret: bool = True,
                                rescale: str | None = None):
    """Phase-1 stats for the baseline kernel. S == n·npb·block (pre-padded).
    Returns (m, l, acc): [BG,n,H], [BG,n,H], [BG,n,H,Dv] (fp32)."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    assert S % (n_splits * block) == 0, (S, n_splits, block)
    npb = S // (n_splits * block)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BG, n_splits, npb),
        in_specs=[
            pl.BlockSpec((1, H, Dk), lambda b, s, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, block, Dk),
                         lambda b, s, j, *_, npb=npb: (b, s * npb + j, 0)),
            pl.BlockSpec((1, block, Dv),
                         lambda b, s, j, *_, npb=npb: (b, s * npb + j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),
            pl.BlockSpec((1, 1, H, Dv), lambda b, s, j, *_: (b, s, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, Dv), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_partial_body, scale=scale, block=block, npb=npb,
                          rescale=softmax_state.resolve(rescale)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BG, n_splits, H), jnp.float32),
            jax.ShapeDtypeStruct((BG, n_splits, H), jnp.float32),
            jax.ShapeDtypeStruct((BG, n_splits, H, Dv), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length.astype(jnp.int32), q, k, v)
