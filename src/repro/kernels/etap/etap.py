"""ETAP decode-attention Pallas TPU kernel (paper Algorithm 1, TPU-adapted).

Per (batch-group, KV-block) grid step the kernel computes the *transposed*
attention update:

    Sᵀ_j = K_j Qᵀ            [B_kv, H]   (KV block length on the GEMM M dim)
    m, ℓ  : per-COLUMN online-softmax stats            [1, H]
    Accᵀ += Vᵀ_j Pᵀ_j         [Dv, H]    (contraction over the long KV axis)
    epilogue: O = (Accᵀ / ℓ)ᵀ  [H, Dv]   (the single final transpose)

The HBM→VMEM producer pipeline of the paper's warpgroup1 is Pallas grid
pipelining (serial KV grid dimension, double-buffered by Mosaic); see
DESIGN.md §2. The MLA-fused variant streams the 576-wide latent cache once
and reuses its first Dv columns as V — one HBM stream for both GEMMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import softmax_state

NEG_INF = softmax_state.NEG_INF


def _dequant(blk, sz_ref):
    """Expand a quantized KV block in registers: codes [block, D] +
    per-row (scale, zp) [block, 2] -> fp32 rows.  Delegates to THE dequant
    definition (runtime.paged_cache.dequantize_rows — the loaded blk/sz
    are plain jnp values inside the Pallas body, so the runtime affine
    traces directly): kernel, XLA gather twin, and oracle literally share
    one function and cannot drift.  sz_ref None is the fp passthrough."""
    if sz_ref is None:
        return blk
    from repro.runtime.paged_cache import dequantize_rows
    return dequantize_rows(blk, sz_ref[0].astype(jnp.float32))


def _etap_body(length_ref, q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *, scale: float, block: int,
               nb: int, fused_dv: int, rescale: str,
               k_sz_ref=None, v_sz_ref=None):
    """Shared kernel body. With fused_dv > 0, v_ref is None and V is the
    first fused_dv columns of the K (latent) block.  With k_sz_ref /
    v_sz_ref set, the K/V blocks arrive as int8/fp8 codes and are
    dequantized in registers before the dot (DESIGN.md §11); the softmax
    statistics and the accumulator are fp32 either way.  The online-softmax
    state lives in the (m, l, acc) scratch refs and is advanced exclusively
    through :mod:`repro.kernels.softmax_state` (``rescale`` selects the
    mul/amla recurrence)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        softmax_state.init_refs(m_ref, l_ref, acc_ref)

    k_blk = _dequant(k_ref[0], k_sz_ref)               # [block, Dk]
    q = q_ref[0]                                       # [H, Dk]
    if k_sz_ref is not None:
        q = q.astype(jnp.float32)                      # match dequanted K
    # Sᵀ = K·Qᵀ — context block on M, heads on N (no M padding waste).
    sT = jax.lax.dot_general(
        k_blk, q, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [block, H]

    length = length_ref[pl.program_id(0)]
    pos = j * block + jax.lax.broadcasted_iota(jnp.int32, sT.shape, 0)
    sT = jnp.where(pos < length, sT, NEG_INF)

    v_blk = k_blk[:, :fused_dv] if fused_dv else _dequant(v_ref[0], v_sz_ref)
    # Accᵀ += Vᵀ·Pᵀ — contraction over the KV block.
    m_ref[...], l_ref[...], acc_ref[...] = softmax_state.update(
        (m_ref[...], l_ref[...], acc_ref[...]), sT,
        lambda p: jax.lax.dot_general(
            v_blk, p, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),       # [Dv, H]
        axis=0, mode=rescale)

    @pl.when(j == nb - 1)
    def _epilogue():
        o_ref[0] = softmax_state.finalize(
            (None, l_ref[...], acc_ref[...])).T.astype(o_ref.dtype)


def _body_fused(length_ref, q_ref, k_ref, o_ref, acc, m, l, **kw):
    _etap_body(length_ref, q_ref, k_ref, None, o_ref, acc, m, l, **kw)


# The paged bodies are the SAME math: the block table only changes *which*
# pool block the BlockSpec index map DMAs in per grid step (scalar-prefetch
# gather — see _paged_call); logical positions / masking are untouched, so
# paged output is bit-identical to the dense kernel at equal block size.
def _paged_body(length_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                acc, m, l, **kw):
    _etap_body(length_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l, **kw)


def _paged_body_fused(length_ref, table_ref, q_ref, k_ref, o_ref,
                      acc, m, l, **kw):
    _etap_body(length_ref, q_ref, k_ref, None, o_ref, acc, m, l, **kw)


# Quantized paged bodies: the sz pool rides as one more gathered operand
# (same table deref as its code pool), dequant happens in _etap_body.
def _paged_body_quant(length_ref, table_ref, q_ref, k_ref, k_sz_ref,
                      v_ref, v_sz_ref, o_ref, acc, m, l, **kw):
    _etap_body(length_ref, q_ref, k_ref, v_ref, o_ref, acc, m, l,
               k_sz_ref=k_sz_ref, v_sz_ref=v_sz_ref, **kw)


def _paged_body_quant_fused(length_ref, table_ref, q_ref, k_ref, k_sz_ref,
                            o_ref, acc, m, l, **kw):
    _etap_body(length_ref, q_ref, k_ref, None, o_ref, acc, m, l,
               k_sz_ref=k_sz_ref, **kw)


def _call(q, k, v, length, *, scale, block, interpret, fused_dv, rescale):
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = fused_dv or v.shape[2]
    block = min(block, S)
    assert S % block == 0, (S, block)
    nb = S // block

    in_specs = [
        pl.BlockSpec((1, H, Dk), lambda b, j, *_: (b, 0, 0)),      # q
        pl.BlockSpec((1, block, Dk), lambda b, j, *_: (b, j, 0)),  # k (or latent)
    ]
    operands = [q, k]
    if not fused_dv:
        in_specs.append(pl.BlockSpec((1, block, Dv), lambda b, j, *_: (b, j, 0)))
        operands.append(v)

    kw = dict(scale=scale, block=block, nb=nb, fused_dv=fused_dv,
              rescale=softmax_state.resolve(rescale))
    body = functools.partial(_body_fused if fused_dv else _etap_body, **kw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BG, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, Dv), lambda b, j, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Dv, H), jnp.float32),                  # Accᵀ
            pltpu.VMEM((1, H), jnp.float32),                   # m
            pltpu.VMEM((1, H), jnp.float32),                   # ℓ
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BG, H, Dv), (v if v is not None else k).dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(length.astype(jnp.int32), *operands)


def etap_decode_pallas(q, k, v, length, *, scale: float, block: int = 512,
                       interpret: bool = True, rescale: str | None = None):
    """Generic (separate-V) ETAP decode kernel."""
    return _call(q, k, v, length, scale=scale, block=block,
                 interpret=interpret, fused_dv=0, rescale=rescale)


def etap_decode_mla_pallas(q, kv, dv: int, length, *, scale: float,
                           block: int = 512, interpret: bool = True,
                           rescale: str | None = None):
    """MLA-fused ETAP: single latent stream, V = kv[..., :dv]."""
    return _call(q, kv, None, length, scale=scale, block=block,
                 interpret=interpret, fused_dv=dv, rescale=rescale)


# ----------------------------------------------------------- paged variants
def _pool_spec(page, D):
    """BlockSpec gathering pool block ``table[b, j]`` per grid step."""
    return pl.BlockSpec((1, page, D), lambda b, j, lens, tab: (tab[b, j], 0, 0))


def _paged_call(q, pool, v_pool, table, lengths, *, scale, interpret,
                fused_dv, rescale, k_sz=None, v_sz=None):
    """Paged single-pass ETAP: KV lives in a block pool [N, page, D]; the
    block table [B, max_blocks] rides in as a scalar-prefetch operand and
    the K/V BlockSpec index maps dereference it, so each grid step DMAs
    pool block ``table[b, j]`` — the gather happens inside the grid, never
    as a materialized dense copy.  k_sz/v_sz: per-row (scale, zp) pools
    [N, page, 2] for quantized code pools (DESIGN.md §11) — they gather
    through the same table and are expanded in registers."""
    B, H, Dk = q.shape
    page = pool.shape[1]
    nb = table.shape[1]
    Dv = fused_dv or v_pool.shape[2]
    quant = k_sz is not None

    in_specs = [
        pl.BlockSpec((1, H, Dk), lambda b, j, *_: (b, 0, 0)),            # q
        _pool_spec(page, Dk),                                            # pool
    ]
    operands = [q, pool]
    if quant:
        in_specs.append(_pool_spec(page, 2))
        operands.append(k_sz)
    if not fused_dv:
        in_specs.append(_pool_spec(page, Dv))
        operands.append(v_pool)
        if quant:
            in_specs.append(_pool_spec(page, 2))
            operands.append(v_sz)

    kw = dict(scale=scale, block=page, nb=nb, fused_dv=fused_dv,
              rescale=softmax_state.resolve(rescale))
    if quant:
        body = functools.partial(
            _paged_body_quant_fused if fused_dv else _paged_body_quant, **kw)
    else:
        body = functools.partial(
            _paged_body_fused if fused_dv else _paged_body, **kw)

    out_dtype = (q.dtype if quant
                 else (v_pool if v_pool is not None else pool).dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, Dv), lambda b, j, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Dv, H), jnp.float32),                  # Accᵀ
            pltpu.VMEM((1, H), jnp.float32),                   # m
            pltpu.VMEM((1, H), jnp.float32),                   # ℓ
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Dv), out_dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), table.astype(jnp.int32), *operands)


def etap_decode_paged_pallas(q, k_pool, v_pool, table, lengths, *,
                             scale: float, interpret: bool = True,
                             k_sz=None, v_sz=None,
                             rescale: str | None = None):
    """Paged (separate-V) ETAP decode kernel. q: [B,H,Dk]; pools
    [N,page,D*]; table: [B,max_blocks]; lengths: [B]. Returns [B,H,Dv].
    k_sz/v_sz: (scale, zp) pools when k_pool/v_pool hold int8/fp8 codes."""
    return _paged_call(q, k_pool, v_pool, table, lengths, scale=scale,
                       interpret=interpret, fused_dv=0, rescale=rescale,
                       k_sz=k_sz, v_sz=v_sz)


def etap_decode_mla_paged_pallas(q, kv_pool, dv: int, table, lengths, *,
                                 scale: float, interpret: bool = True,
                                 kv_sz=None, rescale: str | None = None):
    """Paged MLA-fused ETAP: single latent pool, V = pool[..., :dv].
    kv_sz: (scale, zp) pool when kv_pool holds int8/fp8 codes — V is
    sliced AFTER the affine, so one sz pair serves both operands."""
    return _paged_call(q, kv_pool, None, table, lengths, scale=scale,
                       interpret=interpret, fused_dv=dv, rescale=rescale,
                       k_sz=kv_sz)


# ---------------------------------------------------------- chunked prefill
def _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                       acc_ref, m_ref, l_ref, *, scale: float, page: int,
                       nb: int, heads: int, fused_dv: int, rescale: str,
                       k_sz_ref=None, v_sz_ref=None, qpos_ref=None):
    """Chunked paged ETAP prefill (DESIGN.md §9): the decode body with the
    single query row widened to a [Cq, H] tile, flattened to CH = Cq*H
    online-softmax columns.  The KV walk streams the sequence's pool blocks
    (chunk rows included — the caller appends the chunk before attending),
    and the mask is CAUSAL per column: key position j*page+r is live for
    column c iff  r_pos <= start + c // H  (query c//H is the chunk-local
    row, start the tokens already in the pool).  Blocks past the chunk end
    are fully masked and drop out with weight exp(-inf - m) = 0; block 0 of
    the walk always holds position 0, so no column is ever all-masked.

    ``qpos_ref`` is the VERIFY generalization (DESIGN.md §14): an explicit
    per-column absolute query position [1, CH] replaces the derived
    ``start + c // H`` — the draft-verification mask where each scored
    chunk row attends to exactly the pool rows at or before its own
    position, independent of how the chunk maps onto the pool tail."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        softmax_state.init_refs(m_ref, l_ref, acc_ref)

    k_blk = _dequant(k_ref[0], k_sz_ref)               # [page, Dk]
    q = q_ref[0]                                       # [CH, Dk]
    if k_sz_ref is not None:
        q = q.astype(jnp.float32)                      # match dequanted K
    # Sᵀ = K·Qᵀ — pool block rows on M, the Cq*H query tile on N.
    sT = jax.lax.dot_general(
        k_blk, q, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [page, CH]

    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, sT.shape, 0)
    if qpos_ref is None:
        start = start_ref[pl.program_id(0)]
        qpos = start + jax.lax.broadcasted_iota(
            jnp.int32, sT.shape, 1) // heads
    else:
        qpos = qpos_ref[0][None, :]                    # [1, CH] per-column
    sT = jnp.where(kpos <= qpos, sT, NEG_INF)          # causal chunk-vs-pool

    v_blk = k_blk[:, :fused_dv] if fused_dv else _dequant(v_ref[0], v_sz_ref)
    m_ref[...], l_ref[...], acc_ref[...] = softmax_state.update(
        (m_ref[...], l_ref[...], acc_ref[...]), sT,
        lambda p: jax.lax.dot_general(
            v_blk, p, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),       # [Dv, CH]
        axis=0, mode=rescale)

    @pl.when(j == nb - 1)
    def _epilogue():
        o_ref[0] = softmax_state.finalize(
            (None, l_ref[...], acc_ref[...])).T.astype(o_ref.dtype)


def _prefill_body_fused(start_ref, table_ref, q_ref, k_ref, o_ref,
                        acc, m, l, **kw):
    _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, None, o_ref,
                       acc, m, l, **kw)


def _prefill_body_quant(start_ref, table_ref, q_ref, k_ref, k_sz_ref,
                        v_ref, v_sz_ref, o_ref, acc, m, l, **kw):
    _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                       acc, m, l, k_sz_ref=k_sz_ref, v_sz_ref=v_sz_ref, **kw)


def _prefill_body_quant_fused(start_ref, table_ref, q_ref, k_ref, k_sz_ref,
                              o_ref, acc, m, l, **kw):
    _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, None, o_ref,
                       acc, m, l, k_sz_ref=k_sz_ref, **kw)


# Verify bodies (DESIGN.md §14): the prefill bodies with the per-column
# query-position operand riding directly after q — same math, explicit mask.
def _verify_body(start_ref, table_ref, q_ref, qpos_ref, k_ref, v_ref, o_ref,
                 acc, m, l, **kw):
    _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                       acc, m, l, qpos_ref=qpos_ref, **kw)


def _verify_body_fused(start_ref, table_ref, q_ref, qpos_ref, k_ref, o_ref,
                       acc, m, l, **kw):
    _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, None, o_ref,
                       acc, m, l, qpos_ref=qpos_ref, **kw)


def _verify_body_quant(start_ref, table_ref, q_ref, qpos_ref, k_ref,
                       k_sz_ref, v_ref, v_sz_ref, o_ref, acc, m, l, **kw):
    _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                       acc, m, l, qpos_ref=qpos_ref, k_sz_ref=k_sz_ref,
                       v_sz_ref=v_sz_ref, **kw)


def _verify_body_quant_fused(start_ref, table_ref, q_ref, qpos_ref, k_ref,
                             k_sz_ref, o_ref, acc, m, l, **kw):
    _etap_prefill_body(start_ref, table_ref, q_ref, k_ref, None, o_ref,
                       acc, m, l, qpos_ref=qpos_ref, k_sz_ref=k_sz_ref, **kw)


def _prefill_call(q, pool, v_pool, table, start, *, heads, scale, interpret,
                  fused_dv, rescale, k_sz=None, v_sz=None, qpos=None):
    B, CH, Dk = q.shape
    page = pool.shape[1]
    nb = table.shape[1]
    Dv = fused_dv or v_pool.shape[2]
    quant = k_sz is not None

    in_specs = [
        pl.BlockSpec((1, CH, Dk), lambda b, j, *_: (b, 0, 0)),           # q
    ]
    operands = [q]
    if qpos is not None:
        # per-column absolute query positions: a whole [1, CH] int32 row per
        # batch step (VMEM vector compare — no SMEM vector indexing)
        in_specs.append(pl.BlockSpec((1, CH), lambda b, j, *_: (b, 0)))
        operands.append(qpos.astype(jnp.int32))
    in_specs.append(_pool_spec(page, Dk))                                # pool
    operands.append(pool)
    if quant:
        in_specs.append(_pool_spec(page, 2))
        operands.append(k_sz)
    if not fused_dv:
        in_specs.append(_pool_spec(page, Dv))
        operands.append(v_pool)
        if quant:
            in_specs.append(_pool_spec(page, 2))
            operands.append(v_sz)

    kw = dict(scale=scale, page=page, nb=nb, heads=heads, fused_dv=fused_dv,
              rescale=softmax_state.resolve(rescale))
    if qpos is not None:
        body = functools.partial(
            (_verify_body_quant_fused if fused_dv else _verify_body_quant)
            if quant else
            (_verify_body_fused if fused_dv else _verify_body), **kw)
    elif quant:
        body = functools.partial(
            _prefill_body_quant_fused if fused_dv else _prefill_body_quant,
            **kw)
    else:
        body = functools.partial(
            _prefill_body_fused if fused_dv else _etap_prefill_body, **kw)

    out_dtype = (q.dtype if quant
                 else (v_pool if v_pool is not None else pool).dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, CH, Dv), lambda b, j, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Dv, CH), jnp.float32),                 # Accᵀ
            pltpu.VMEM((1, CH), jnp.float32),                  # m
            pltpu.VMEM((1, CH), jnp.float32),                  # ℓ
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, CH, Dv), out_dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(start.astype(jnp.int32), table.astype(jnp.int32), *operands)


def etap_prefill_paged_pallas(q, k_pool, v_pool, table, start, *,
                              scale: float, interpret: bool = True,
                              k_sz=None, v_sz=None,
                              rescale: str | None = None):
    """Paged (separate-V) chunked ETAP prefill. q: [B,Cq,H,Dk]; pools
    [N,page,D*]; table [B,max_blocks]; start [B] = tokens already in the
    pool BEFORE this chunk (the chunk's own rows must already be appended).
    Returns [B,Cq,H,Dv].  k_sz/v_sz: (scale, zp) pools for quantized
    code pools."""
    B, Cq, H, Dk = q.shape
    o = _prefill_call(q.reshape(B, Cq * H, Dk), k_pool, v_pool, table, start,
                      heads=H, scale=scale, interpret=interpret, fused_dv=0,
                      rescale=rescale, k_sz=k_sz, v_sz=v_sz)
    return o.reshape(B, Cq, H, o.shape[-1])


def etap_prefill_mla_paged_pallas(q, kv_pool, dv: int, table, start, *,
                                  scale: float, interpret: bool = True,
                                  kv_sz=None, rescale: str | None = None):
    """Paged MLA-fused chunked prefill: single latent pool, V = pool[..., :dv]."""
    B, Cq, H, Dk = q.shape
    o = _prefill_call(q.reshape(B, Cq * H, Dk), kv_pool, None, table, start,
                      heads=H, scale=scale, interpret=interpret, fused_dv=dv,
                      rescale=rescale, k_sz=kv_sz)
    return o.reshape(B, Cq, H, dv)


# -------------------------------------------------- draft verification
def _expand_qpos(qpos, H):
    """[B, Cq] absolute query positions -> the [B, Cq*H] per-column row the
    kernel compares against (column c*H + h belongs to query row c)."""
    return jnp.repeat(qpos.astype(jnp.int32), H, axis=1)


def etap_verify_paged_pallas(q, k_pool, v_pool, table, start, qpos, *,
                             scale: float, interpret: bool = True,
                             k_sz=None, v_sz=None,
                             rescale: str | None = None):
    """Paged (separate-V) draft-verify attention (DESIGN.md §14): the
    chunked-prefill kernel with an EXPLICIT per-query position operand.
    q: [B,Cq,H,Dk] — the Cq drafted rows (already appended to the pool);
    qpos: [B,Cq] int32 absolute positions — row c attends to pool rows at
    positions <= qpos[b, c].  A linear draft chain with
    ``qpos = start + arange(Cq)`` is bit-identical to the prefill kernel;
    the explicit operand is what tree-shaped position layouts plug into."""
    B, Cq, H, Dk = q.shape
    o = _prefill_call(q.reshape(B, Cq * H, Dk), k_pool, v_pool, table, start,
                      heads=H, scale=scale, interpret=interpret, fused_dv=0,
                      rescale=rescale, k_sz=k_sz, v_sz=v_sz,
                      qpos=_expand_qpos(qpos, H))
    return o.reshape(B, Cq, H, o.shape[-1])


def etap_verify_mla_paged_pallas(q, kv_pool, dv: int, table, start, qpos, *,
                                 scale: float, interpret: bool = True,
                                 kv_sz=None, rescale: str | None = None):
    """Paged MLA-fused draft-verify: single latent pool, V = pool[..., :dv],
    explicit per-query positions (see :func:`etap_verify_paged_pallas`)."""
    B, Cq, H, Dk = q.shape
    o = _prefill_call(q.reshape(B, Cq * H, Dk), kv_pool, None, table, start,
                      heads=H, scale=scale, interpret=interpret, fused_dv=dv,
                      rescale=rescale, k_sz=kv_sz, qpos=_expand_qpos(qpos, H))
    return o.reshape(B, Cq, H, dv)


# ------------------------------------------------------- split-KV (phase 1)
def _etap_partial_body(length_ref, q_ref, k_ref, v_ref,
                       m_out_ref, l_out_ref, acc_out_ref,
                       acc_ref, m_ref, l_ref, *, scale: float, block: int,
                       npb: int, fused_dv: int, rescale: str,
                       k_sz_ref=None, v_sz_ref=None):
    """Split-KV partial: same transposed update as :func:`_etap_body`, on a
    3-D ``(BG, n_splits, nb_per_split)`` grid.  Each (b, split) pair owns a
    contiguous KV segment and emits raw ``(m, ℓ, Accᵀ)`` stats instead of O —
    the combine kernel (phase 2, ``combine.py``) merges them in the stat
    domain, so splits are fully independent and can run on different cores."""
    s = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        softmax_state.init_refs(m_ref, l_ref, acc_ref)

    k_blk = _dequant(k_ref[0], k_sz_ref)               # [block, Dk]
    q = q_ref[0]                                       # [H, Dk]
    if k_sz_ref is not None:
        q = q.astype(jnp.float32)                      # match dequanted K
    sT = jax.lax.dot_general(
        k_blk, q, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # [block, H]

    length = length_ref[pl.program_id(0)]
    pos = (s * npb + j) * block + jax.lax.broadcasted_iota(
        jnp.int32, sT.shape, 0)
    sT = jnp.where(pos < length, sT, NEG_INF)

    v_blk = k_blk[:, :fused_dv] if fused_dv else _dequant(v_ref[0], v_sz_ref)
    m_ref[...], l_ref[...], acc_ref[...] = softmax_state.update(
        (m_ref[...], l_ref[...], acc_ref[...]), sT,
        lambda p: jax.lax.dot_general(
            v_blk, p, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32),       # [Dv, H]
        axis=0, mode=rescale)

    @pl.when(j == npb - 1)
    def _emit():
        m_out_ref[0] = m_ref[...]
        l_out_ref[0] = l_ref[...]
        acc_out_ref[0, 0] = acc_ref[...]


def _partial_body_fused(length_ref, q_ref, k_ref, m_out, l_out, acc_out,
                        acc, m, l, **kw):
    _etap_partial_body(length_ref, q_ref, k_ref, None, m_out, l_out, acc_out,
                       acc, m, l, **kw)


def etap_partial_pallas(q, k, v, length, *, scale: float, block: int,
                        n_splits: int, interpret: bool = True,
                        fused_dv: int = 0, rescale: str | None = None):
    """Phase-1 split-KV ETAP kernel.

    q: [BG,H,Dk]; k: [BG,S,Dk] with S == n_splits * nb_per_split * block
    (callers pad — the tail is masked via `length`).  Returns fp32 partial
    stats (m, l, accT): [BG,n_splits,H], [BG,n_splits,H], [BG,n_splits,Dv,H].
    With fused_dv > 0, v is ignored and V = k[..., :fused_dv] (MLA latent)."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = fused_dv or v.shape[2]
    assert S % (n_splits * block) == 0, (S, n_splits, block)
    npb = S // (n_splits * block)

    in_specs = [
        pl.BlockSpec((1, H, Dk), lambda b, s, j, *_: (b, 0, 0)),       # q
        pl.BlockSpec((1, block, Dk),
                     lambda b, s, j, *_, npb=npb: (b, s * npb + j, 0)),  # k
    ]
    operands = [q, k]
    if not fused_dv:
        in_specs.append(pl.BlockSpec(
            (1, block, Dv), lambda b, s, j, *_, npb=npb: (b, s * npb + j, 0)))
        operands.append(v)

    kw = dict(scale=scale, block=block, npb=npb, fused_dv=fused_dv,
              rescale=softmax_state.resolve(rescale))
    body = functools.partial(
        _partial_body_fused if fused_dv else _etap_partial_body, **kw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BG, n_splits, npb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),      # m
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),      # ℓ
            pl.BlockSpec((1, 1, Dv, H), lambda b, s, j, *_: (b, s, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Dv, H), jnp.float32),                  # Accᵀ
            pltpu.VMEM((1, H), jnp.float32),                   # m
            pltpu.VMEM((1, H), jnp.float32),                   # ℓ
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BG, n_splits, H), jnp.float32),
            jax.ShapeDtypeStruct((BG, n_splits, H), jnp.float32),
            jax.ShapeDtypeStruct((BG, n_splits, Dv, H), jnp.float32),
        ],
        # splits are independent work items — only the within-split KV walk
        # is a sequential accumulation.
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length.astype(jnp.int32), *operands)


def _paged_partial_body(length_ref, table_ref, q_ref, k_ref, v_ref,
                        m_out, l_out, acc_out, acc, m, l, **kw):
    _etap_partial_body(length_ref, q_ref, k_ref, v_ref, m_out, l_out,
                       acc_out, acc, m, l, **kw)


def _paged_partial_body_fused(length_ref, table_ref, q_ref, k_ref,
                              m_out, l_out, acc_out, acc, m, l, **kw):
    _etap_partial_body(length_ref, q_ref, k_ref, None, m_out, l_out,
                       acc_out, acc, m, l, **kw)


def _paged_partial_body_quant(length_ref, table_ref, q_ref, k_ref, k_sz_ref,
                              v_ref, v_sz_ref, m_out, l_out, acc_out,
                              acc, m, l, **kw):
    _etap_partial_body(length_ref, q_ref, k_ref, v_ref, m_out, l_out,
                       acc_out, acc, m, l, k_sz_ref=k_sz_ref,
                       v_sz_ref=v_sz_ref, **kw)


def _paged_partial_body_quant_fused(length_ref, table_ref, q_ref, k_ref,
                                    k_sz_ref, m_out, l_out, acc_out,
                                    acc, m, l, **kw):
    _etap_partial_body(length_ref, q_ref, k_ref, None, m_out, l_out,
                       acc_out, acc, m, l, k_sz_ref=k_sz_ref, **kw)


def etap_paged_partial_pallas(q, k_pool, v_pool, table, lengths, *,
                              scale: float, n_splits: int,
                              interpret: bool = True, fused_dv: int = 0,
                              k_sz=None, v_sz=None,
                              rescale: str | None = None):
    """Phase-1 split-KV over a PAGED cache: same (b, split, block-walk) grid
    as :func:`etap_partial_pallas`, but each grid step's KV block is pool
    block ``table[b, s*npb + j]`` (scalar-prefetch gather).  Splits are cut
    at page granularity — callers pad the table to an ``n_splits * npb``
    width with null blocks (masked via `lengths`), so ``n_splits`` composes
    with paging with no repacking.  Returns fp32 (m, l, accT) stats.
    k_sz/v_sz: (scale, zp) pools for quantized code pools — the partial
    stats stay fp32 regardless of the storage layout."""
    B, H, Dk = q.shape
    page = k_pool.shape[1]
    nb = table.shape[1]
    Dv = fused_dv or v_pool.shape[2]
    assert nb % n_splits == 0, (nb, n_splits)
    npb = nb // n_splits
    quant = k_sz is not None

    def split_pool_spec(D):
        return pl.BlockSpec(
            (1, page, D),
            lambda b, s, j, lens, tab, npb=npb: (tab[b, s * npb + j], 0, 0))

    in_specs = [
        pl.BlockSpec((1, H, Dk), lambda b, s, j, *_: (b, 0, 0)),         # q
        split_pool_spec(Dk),                                             # pool
    ]
    operands = [q, k_pool]
    if quant:
        in_specs.append(split_pool_spec(2))
        operands.append(k_sz)
    if not fused_dv:
        in_specs.append(split_pool_spec(Dv))
        operands.append(v_pool)
        if quant:
            in_specs.append(split_pool_spec(2))
            operands.append(v_sz)

    kw = dict(scale=scale, block=page, npb=npb, fused_dv=fused_dv,
              rescale=softmax_state.resolve(rescale))
    if quant:
        body = functools.partial(
            _paged_partial_body_quant_fused if fused_dv
            else _paged_partial_body_quant, **kw)
    else:
        body = functools.partial(
            _paged_partial_body_fused if fused_dv else _paged_partial_body,
            **kw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_splits, npb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),      # m
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),      # ℓ
            pl.BlockSpec((1, 1, Dv, H), lambda b, s, j, *_: (b, s, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Dv, H), jnp.float32),                  # Accᵀ
            pltpu.VMEM((1, H), jnp.float32),                   # m
            pltpu.VMEM((1, H), jnp.float32),                   # ℓ
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, n_splits, H), jnp.float32),
            jax.ShapeDtypeStruct((B, n_splits, H), jnp.float32),
            jax.ShapeDtypeStruct((B, n_splits, Dv, H), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), table.astype(jnp.int32), *operands)
