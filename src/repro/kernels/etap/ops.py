"""jit'd public wrappers for the ETAP kernels: shape normalization (pad S to
a block/split multiple — masked via `length`), dtype checks, MLA-fused and
split-KV two-phase entry points.

Every entry point takes ``rescale`` (None → the process default mode) and is
wrapped by :func:`softmax_state.jit_with_rescale`, which resolves the mode
BEFORE the jit cache — flipping the serve-level default can never serve a
stale trace, and the resolved string is a static cache key."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import softmax_state
from repro.kernels.etap.combine import combine_splits
from repro.kernels.etap.etap import (etap_decode_mla_paged_pallas,
                                     etap_decode_mla_pallas,
                                     etap_decode_paged_pallas,
                                     etap_decode_pallas,
                                     etap_paged_partial_pallas,
                                     etap_partial_pallas,
                                     etap_prefill_mla_paged_pallas,
                                     etap_prefill_paged_pallas)
from repro.kernels.etap.schedule import (paged_split_geometry, plan_splits,
                                         plan_splits_paged, split_geometry)


def _pad_seq(x, multiple: int):
    S = x.shape[1]
    pad = (-S) % multiple
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@softmax_state.jit_with_rescale(
    static_argnames=("scale", "block", "interpret"))
def etap_decode(q, k, v, length=None, *, scale: float, block: int = 512,
                interpret: bool = True, rescale: str | None = None):
    """ETAP decode attention. q: [BG,H,Dk]; k: [BG,S,Dk]; v: [BG,S,Dv];
    length: [BG] valid-prefix lengths (None = all S). Returns [BG,H,Dv]."""
    BG, _, _ = q.shape
    S = k.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    k = _pad_seq(k, block)     # padded tail is masked out via `length`
    v = _pad_seq(v, block)
    return etap_decode_pallas(q, k, v, length, scale=scale, block=block,
                              interpret=interpret, rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("dv", "scale", "block", "interpret"))
def etap_decode_mla(q, kv, dv: int, length=None, *, scale: float,
                    block: int = 512, interpret: bool = True,
                    rescale: str | None = None):
    """MLA-fused ETAP: one latent stream [BG,S,latent]; V = kv[..., :dv]."""
    BG = q.shape[0]
    S = kv.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    kv = _pad_seq(kv, block)
    return etap_decode_mla_pallas(q, kv, dv, length, scale=scale, block=block,
                                  interpret=interpret, rescale=rescale)


# ------------------------------------------------------ split-KV two-phase
def _partial(q, kv, v, length, *, scale, block, n_splits, interpret,
             fused_dv, rescale):
    """Pad S to a (n_splits · block) multiple and run the phase-1 kernel.
    n_splits is re-derived through the shared geometry, so a request for
    more splits than there are KV blocks degrades to fewer non-empty
    splits instead of launching zero-length grid rows."""
    block, n_splits, _, target = split_geometry(kv.shape[1], block, n_splits)
    kv = _pad_seq(kv, target)
    if v is not None:
        v = _pad_seq(v, target)
    return etap_partial_pallas(q, kv, v, length, scale=scale, block=block,
                               n_splits=n_splits, interpret=interpret,
                               fused_dv=fused_dv, rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("scale", "block", "n_splits", "interpret"))
def etap_partial(q, k, v, length=None, *, scale: float, block: int = 512,
                 n_splits: int = 2, interpret: bool = True,
                 rescale: str | None = None):
    """Phase-1 split-KV stats. Returns (m, l, accT):
    [BG,n,H], [BG,n,H], [BG,n,Dv,H] (fp32)."""
    BG = q.shape[0]
    if length is None:
        length = jnp.full((BG,), k.shape[1], jnp.int32)
    return _partial(q, k, v, length, scale=scale, block=block,
                    n_splits=n_splits, interpret=interpret, fused_dv=0,
                    rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("dv", "scale", "block", "n_splits", "interpret"))
def etap_partial_mla(q, kv, dv: int, length=None, *, scale: float,
                     block: int = 512, n_splits: int = 2,
                     interpret: bool = True, rescale: str | None = None):
    """Phase-1 split-KV stats, MLA-fused (V = kv[..., :dv])."""
    BG = q.shape[0]
    if length is None:
        length = jnp.full((BG,), kv.shape[1], jnp.int32)
    return _partial(q, kv, None, length, scale=scale, block=block,
                    n_splits=n_splits, interpret=interpret, fused_dv=dv,
                    rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("scale", "block", "n_splits", "combine", "interpret"))
def etap_decode_splitkv(q, k, v, length=None, *, scale: float,
                        block: int = 512, n_splits: int = 0,
                        combine: str = "pallas", interpret: bool = True,
                        rescale: str | None = None):
    """Two-phase split-KV ETAP decode. n_splits = 0 → auto (scheduler);
    n_splits = 1 routes to the single-pass kernel (bit-identical — the
    combine weights degenerate to exp(0) = 1, so the two-phase path computes
    the same epilogue; routing just skips the stats round-trip)."""
    BG, H, _ = q.shape
    S = k.shape[1]
    if not n_splits:
        n_splits = plan_splits(BG, S, H, v.shape[2], block=block).n_splits
    n_splits = split_geometry(S, block, n_splits)[1]    # effective count
    if n_splits <= 1:
        return etap_decode(q, k, v, length, scale=scale, block=block,
                           interpret=interpret, rescale=rescale)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    m, l, accT = _partial(q, k, v, length, scale=scale, block=block,
                          n_splits=n_splits, interpret=interpret, fused_dv=0,
                          rescale=rescale)
    return combine_splits(m, l, accT, transposed=True, out_dtype=v.dtype,
                          combine=combine, interpret=interpret,
                          rescale=rescale)


# ------------------------------------------------------------------- paged
def _pad_table(table, multiple: int):
    """Pad the block table to a column multiple with null blocks (id 0);
    padded entries are masked via `lengths` exactly like the dense padded
    tail, so split geometry never repacks the pool."""
    nb = table.shape[1]
    pad = (-nb) % multiple
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    return table


@softmax_state.jit_with_rescale(static_argnames=("scale", "interpret"))
def etap_decode_paged(q, k_pool, v_pool, table, lengths, *, scale: float,
                      interpret: bool = True, k_sz=None, v_sz=None,
                      rescale: str | None = None):
    """Paged ETAP decode. q: [B,H,Dk]; pools: [N,page,D*]; table:
    [B,max_blocks] int32; lengths: [B]. Returns [B,H,Dv].  Bit-identical
    to :func:`etap_decode` at block == page on the same logical rows.
    k_sz/v_sz: per-row (scale, zp) pools [N,page,2] when the pools hold
    int8/fp8 codes (in-register dequant, DESIGN.md §11)."""
    return etap_decode_paged_pallas(q, k_pool, v_pool, table, lengths,
                                    scale=scale, interpret=interpret,
                                    k_sz=k_sz, v_sz=v_sz, rescale=rescale)


@softmax_state.jit_with_rescale(static_argnames=("dv", "scale", "interpret"))
def etap_decode_mla_paged(q, kv_pool, dv: int, table, lengths, *,
                          scale: float, interpret: bool = True, kv_sz=None,
                          rescale: str | None = None):
    """Paged MLA-fused ETAP: one latent pool, V = pool[..., :dv]."""
    return etap_decode_mla_paged_pallas(q, kv_pool, dv, table, lengths,
                                        scale=scale, interpret=interpret,
                                        kv_sz=kv_sz, rescale=rescale)


@softmax_state.jit_with_rescale(static_argnames=("scale", "interpret"))
def etap_prefill_paged(q, k_pool, v_pool, table, start, *, scale: float,
                       interpret: bool = True, k_sz=None, v_sz=None,
                       rescale: str | None = None):
    """Chunked paged ETAP prefill (separate-V). q: [B,Cq,H,Dk]; pools:
    [N,page,D*]; table: [B,max_blocks] int32; start: [B] tokens already in
    the pool before the chunk (whose rows must already be appended).
    Returns [B,Cq,H,Dv] — causal within the chunk, full over the pool."""
    return etap_prefill_paged_pallas(q, k_pool, v_pool, table, start,
                                     scale=scale, interpret=interpret,
                                     k_sz=k_sz, v_sz=v_sz, rescale=rescale)


@softmax_state.jit_with_rescale(static_argnames=("dv", "scale", "interpret"))
def etap_prefill_mla_paged(q, kv_pool, dv: int, table, start, *,
                           scale: float, interpret: bool = True, kv_sz=None,
                           rescale: str | None = None):
    """Chunked paged MLA-fused ETAP prefill: one latent pool, V = pool[..., :dv]."""
    return etap_prefill_mla_paged_pallas(q, kv_pool, dv, table, start,
                                         scale=scale, interpret=interpret,
                                         kv_sz=kv_sz, rescale=rescale)


def _paged_partial(q, k_pool, v_pool, table, lengths, *, scale, n_splits,
                   interpret, fused_dv, rescale, k_sz=None, v_sz=None):
    n_splits, npb, padded_nb = paged_split_geometry(table.shape[1], n_splits)
    table = _pad_table(table, padded_nb)
    return etap_paged_partial_pallas(q, k_pool, v_pool, table, lengths,
                                     scale=scale, n_splits=n_splits,
                                     interpret=interpret, fused_dv=fused_dv,
                                     k_sz=k_sz, v_sz=v_sz, rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("scale", "n_splits", "combine", "interpret"))
def etap_decode_paged_splitkv(q, k_pool, v_pool, table, lengths, *,
                              scale: float, n_splits: int = 0,
                              combine: str = "pallas",
                              interpret: bool = True, k_sz=None, v_sz=None,
                              rescale: str | None = None):
    """Two-phase split-KV ETAP decode over a paged cache. n_splits = 0 →
    auto via the block-granular scheduler; 1 routes to the single-pass
    paged kernel (bit-identical, same argument as the dense path).
    Requests for more splits than table columns degrade to the effective
    count of the shared geometry (no zero-length splits)."""
    B, H, _ = q.shape
    page = k_pool.shape[1]
    if not n_splits:
        n_splits = plan_splits_paged(B, table.shape[1], page, H,
                                     v_pool.shape[2]).n_splits
    n_splits = paged_split_geometry(table.shape[1], n_splits)[0]
    if n_splits <= 1:
        return etap_decode_paged(q, k_pool, v_pool, table, lengths,
                                 scale=scale, interpret=interpret,
                                 k_sz=k_sz, v_sz=v_sz, rescale=rescale)
    m, l, accT = _paged_partial(q, k_pool, v_pool, table, lengths,
                                scale=scale, n_splits=n_splits,
                                interpret=interpret, fused_dv=0,
                                k_sz=k_sz, v_sz=v_sz, rescale=rescale)
    out_dtype = q.dtype if k_sz is not None else v_pool.dtype
    return combine_splits(m, l, accT, transposed=True,
                          out_dtype=out_dtype, combine=combine,
                          interpret=interpret, rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("dv", "scale", "n_splits", "combine", "interpret"))
def etap_decode_mla_paged_splitkv(q, kv_pool, dv: int, table, lengths, *,
                                  scale: float, n_splits: int = 0,
                                  combine: str = "pallas",
                                  interpret: bool = True, kv_sz=None,
                                  rescale: str | None = None):
    """Two-phase split-KV over a paged MLA latent pool (V = pool[..., :dv])."""
    B, H, _ = q.shape
    page = kv_pool.shape[1]
    if not n_splits:
        n_splits = plan_splits_paged(B, table.shape[1], page, H, dv).n_splits
    n_splits = paged_split_geometry(table.shape[1], n_splits)[0]
    if n_splits <= 1:
        return etap_decode_mla_paged(q, kv_pool, dv, table, lengths,
                                     scale=scale, interpret=interpret,
                                     kv_sz=kv_sz, rescale=rescale)
    m, l, accT = _paged_partial(q, kv_pool, None, table, lengths,
                                scale=scale, n_splits=n_splits,
                                interpret=interpret, fused_dv=dv,
                                k_sz=kv_sz, rescale=rescale)
    out_dtype = q.dtype if kv_sz is not None else kv_pool.dtype
    return combine_splits(m, l, accT, transposed=True,
                          out_dtype=out_dtype, combine=combine,
                          interpret=interpret, rescale=rescale)


@softmax_state.jit_with_rescale(
    static_argnames=("dv", "scale", "block", "n_splits", "combine",
                     "interpret"))
def etap_decode_mla_splitkv(q, kv, dv: int, length=None, *, scale: float,
                            block: int = 512, n_splits: int = 0,
                            combine: str = "pallas", interpret: bool = True,
                            rescale: str | None = None):
    """Two-phase split-KV, MLA-fused single-latent-stream variant."""
    BG, H, _ = q.shape
    S = kv.shape[1]
    if not n_splits:
        n_splits = plan_splits(BG, S, H, dv, block=block).n_splits
    n_splits = split_geometry(S, block, n_splits)[1]    # effective count
    if n_splits <= 1:
        return etap_decode_mla(q, kv, dv, length, scale=scale, block=block,
                               interpret=interpret, rescale=rescale)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    m, l, accT = _partial(q, kv, None, length, scale=scale, block=block,
                          n_splits=n_splits, interpret=interpret, fused_dv=dv,
                          rescale=rescale)
    return combine_splits(m, l, accT, transposed=True, out_dtype=kv.dtype,
                          combine=combine, interpret=interpret,
                          rescale=rescale)
