"""jit'd public wrappers for the ETAP kernels: shape normalization (pad S to
a block/split multiple — masked via `length`), dtype checks, MLA-fused and
split-KV two-phase entry points."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.etap.combine import combine_splits
from repro.kernels.etap.etap import (etap_decode_mla_pallas,
                                     etap_decode_pallas, etap_partial_pallas)
from repro.kernels.etap.schedule import plan_splits, split_geometry


def _pad_seq(x, multiple: int):
    S = x.shape[1]
    pad = (-S) % multiple
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.jit, static_argnames=("scale", "block", "interpret"))
def etap_decode(q, k, v, length=None, *, scale: float, block: int = 512,
                interpret: bool = True):
    """ETAP decode attention. q: [BG,H,Dk]; k: [BG,S,Dk]; v: [BG,S,Dv];
    length: [BG] valid-prefix lengths (None = all S). Returns [BG,H,Dv]."""
    BG, _, _ = q.shape
    S = k.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    k = _pad_seq(k, block)     # padded tail is masked out via `length`
    v = _pad_seq(v, block)
    return etap_decode_pallas(q, k, v, length, scale=scale, block=block,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dv", "scale", "block", "interpret"))
def etap_decode_mla(q, kv, dv: int, length=None, *, scale: float,
                    block: int = 512, interpret: bool = True):
    """MLA-fused ETAP: one latent stream [BG,S,latent]; V = kv[..., :dv]."""
    BG = q.shape[0]
    S = kv.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    kv = _pad_seq(kv, block)
    return etap_decode_mla_pallas(q, kv, dv, length, scale=scale, block=block,
                                  interpret=interpret)


# ------------------------------------------------------ split-KV two-phase
def _partial(q, kv, v, length, *, scale, block, n_splits, interpret, fused_dv):
    """Pad S to a (n_splits · block) multiple and run the phase-1 kernel."""
    block, _, target = split_geometry(kv.shape[1], block, n_splits)
    kv = _pad_seq(kv, target)
    if v is not None:
        v = _pad_seq(v, target)
    return etap_partial_pallas(q, kv, v, length, scale=scale, block=block,
                               n_splits=n_splits, interpret=interpret,
                               fused_dv=fused_dv)


@functools.partial(jax.jit, static_argnames=("scale", "block", "n_splits",
                                             "interpret"))
def etap_partial(q, k, v, length=None, *, scale: float, block: int = 512,
                 n_splits: int = 2, interpret: bool = True):
    """Phase-1 split-KV stats. Returns (m, l, accT):
    [BG,n,H], [BG,n,H], [BG,n,Dv,H] (fp32)."""
    BG = q.shape[0]
    if length is None:
        length = jnp.full((BG,), k.shape[1], jnp.int32)
    return _partial(q, k, v, length, scale=scale, block=block,
                    n_splits=n_splits, interpret=interpret, fused_dv=0)


@functools.partial(jax.jit, static_argnames=("dv", "scale", "block",
                                             "n_splits", "interpret"))
def etap_partial_mla(q, kv, dv: int, length=None, *, scale: float,
                     block: int = 512, n_splits: int = 2,
                     interpret: bool = True):
    """Phase-1 split-KV stats, MLA-fused (V = kv[..., :dv])."""
    BG = q.shape[0]
    if length is None:
        length = jnp.full((BG,), kv.shape[1], jnp.int32)
    return _partial(q, kv, None, length, scale=scale, block=block,
                    n_splits=n_splits, interpret=interpret, fused_dv=dv)


@functools.partial(jax.jit, static_argnames=("scale", "block", "n_splits",
                                             "combine", "interpret"))
def etap_decode_splitkv(q, k, v, length=None, *, scale: float,
                        block: int = 512, n_splits: int = 0,
                        combine: str = "pallas", interpret: bool = True):
    """Two-phase split-KV ETAP decode. n_splits = 0 → auto (scheduler);
    n_splits = 1 routes to the single-pass kernel (bit-identical — the
    combine weights degenerate to exp(0) = 1, so the two-phase path computes
    the same epilogue; routing just skips the stats round-trip)."""
    BG, H, _ = q.shape
    S = k.shape[1]
    if not n_splits:
        n_splits = plan_splits(BG, S, H, v.shape[2], block=block).n_splits
    if n_splits <= 1:
        return etap_decode(q, k, v, length, scale=scale, block=block,
                           interpret=interpret)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    m, l, accT = _partial(q, k, v, length, scale=scale, block=block,
                          n_splits=n_splits, interpret=interpret, fused_dv=0)
    return combine_splits(m, l, accT, transposed=True, out_dtype=v.dtype,
                          combine=combine, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dv", "scale", "block",
                                             "n_splits", "combine",
                                             "interpret"))
def etap_decode_mla_splitkv(q, kv, dv: int, length=None, *, scale: float,
                            block: int = 512, n_splits: int = 0,
                            combine: str = "pallas", interpret: bool = True):
    """Two-phase split-KV, MLA-fused single-latent-stream variant."""
    BG, H, _ = q.shape
    S = kv.shape[1]
    if not n_splits:
        n_splits = plan_splits(BG, S, H, dv, block=block).n_splits
    if n_splits <= 1:
        return etap_decode_mla(q, kv, dv, length, scale=scale, block=block,
                               interpret=interpret)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    m, l, accT = _partial(q, kv, None, length, scale=scale, block=block,
                          n_splits=n_splits, interpret=interpret, fused_dv=dv)
    return combine_splits(m, l, accT, transposed=True, out_dtype=kv.dtype,
                          combine=combine, interpret=interpret)
