"""jit'd public wrappers for the ETAP kernels: shape normalization (pad S to
a block/split multiple — masked via `length`), dtype checks, MLA-fused and
split-KV two-phase entry points.

Every entry point takes one :class:`repro.core.attn_spec.AttnSpec`
(``spec=``) wrapped by :func:`attn_spec.attn_entry`: the spec is
canonicalized BEFORE the jit cache — ``rescale=None`` resolves to the
process default mode (flipping the serve-level default can never serve a
stale trace) and fields the entry's trace ignores are projected to their
defaults (flipping an unused knob never retraces).  Legacy keyword calls
(``scale=``, ``block=``, ``rescale=``, ``n_splits=``, ...) still work
through the shim and emit ``DeprecationWarning``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import attn_spec
from repro.kernels.etap.combine import combine_splits
from repro.kernels.etap.etap import (etap_decode_mla_paged_pallas,
                                     etap_decode_mla_pallas,
                                     etap_decode_paged_pallas,
                                     etap_decode_pallas,
                                     etap_paged_partial_pallas,
                                     etap_partial_pallas,
                                     etap_prefill_mla_paged_pallas,
                                     etap_prefill_paged_pallas,
                                     etap_verify_mla_paged_pallas,
                                     etap_verify_paged_pallas)
from repro.kernels.etap.schedule import (paged_split_geometry, plan_splits,
                                         plan_splits_paged, split_geometry)


def _pad_seq(x, multiple: int):
    S = x.shape[1]
    pad = (-S) % multiple
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@attn_spec.attn_entry(uses=("block", "interpret", "rescale"))
def etap_decode(q, k, v, length=None, *, spec):
    """ETAP decode attention. q: [BG,H,Dk]; k: [BG,S,Dk]; v: [BG,S,Dv];
    length: [BG] valid-prefix lengths (None = all S). Returns [BG,H,Dv]."""
    BG, _, _ = q.shape
    S = k.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(spec.block, S)
    k = _pad_seq(k, block)     # padded tail is masked out via `length`
    v = _pad_seq(v, block)
    return etap_decode_pallas(q, k, v, length, scale=spec.scale, block=block,
                              interpret=spec.interpret, rescale=spec.rescale)


@attn_spec.attn_entry(uses=("block", "interpret", "rescale"),
                      static_argnames=("dv",))
def etap_decode_mla(q, kv, dv: int, length=None, *, spec):
    """MLA-fused ETAP: one latent stream [BG,S,latent]; V = kv[..., :dv]."""
    BG = q.shape[0]
    S = kv.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(spec.block, S)
    kv = _pad_seq(kv, block)
    return etap_decode_mla_pallas(q, kv, dv, length, scale=spec.scale,
                                  block=block, interpret=spec.interpret,
                                  rescale=spec.rescale)


# ------------------------------------------------------ split-KV two-phase
def _partial(q, kv, v, length, *, scale, block, n_splits, interpret,
             fused_dv, rescale):
    """Pad S to a (n_splits · block) multiple and run the phase-1 kernel.
    n_splits is re-derived through the shared geometry, so a request for
    more splits than there are KV blocks degrades to fewer non-empty
    splits instead of launching zero-length grid rows."""
    block, n_splits, _, target = split_geometry(kv.shape[1], block, n_splits)
    kv = _pad_seq(kv, target)
    if v is not None:
        v = _pad_seq(v, target)
    return etap_partial_pallas(q, kv, v, length, scale=scale, block=block,
                               n_splits=n_splits, interpret=interpret,
                               fused_dv=fused_dv, rescale=rescale)


@attn_spec.attn_entry(uses=("block", "kv_splits", "interpret", "rescale"))
def etap_partial(q, k, v, length=None, *, spec):
    """Phase-1 split-KV stats. Returns (m, l, accT):
    [BG,n,H], [BG,n,H], [BG,n,Dv,H] (fp32).  spec.kv_splits None -> 2."""
    BG = q.shape[0]
    if length is None:
        length = jnp.full((BG,), k.shape[1], jnp.int32)
    n_splits = 2 if spec.kv_splits is None else int(spec.kv_splits)
    return _partial(q, k, v, length, scale=spec.scale, block=spec.block,
                    n_splits=n_splits, interpret=spec.interpret, fused_dv=0,
                    rescale=spec.rescale)


@attn_spec.attn_entry(uses=("block", "kv_splits", "interpret", "rescale"),
                      static_argnames=("dv",))
def etap_partial_mla(q, kv, dv: int, length=None, *, spec):
    """Phase-1 split-KV stats, MLA-fused (V = kv[..., :dv])."""
    BG = q.shape[0]
    if length is None:
        length = jnp.full((BG,), kv.shape[1], jnp.int32)
    n_splits = 2 if spec.kv_splits is None else int(spec.kv_splits)
    return _partial(q, kv, None, length, scale=spec.scale, block=spec.block,
                    n_splits=n_splits, interpret=spec.interpret, fused_dv=dv,
                    rescale=spec.rescale)


@attn_spec.attn_entry(uses=("block", "kv_splits", "interpret", "rescale"),
                      static_argnames=("combine",))
def etap_decode_splitkv(q, k, v, length=None, *, spec,
                        combine: str = "pallas"):
    """Two-phase split-KV ETAP decode. spec.kv_splits None/0 → auto
    (scheduler); 1 routes to the single-pass kernel (bit-identical — the
    combine weights degenerate to exp(0) = 1, so the two-phase path computes
    the same epilogue; routing just skips the stats round-trip)."""
    BG, H, _ = q.shape
    S = k.shape[1]
    n_splits = int(spec.kv_splits or 0)
    if not n_splits:
        n_splits = plan_splits(BG, S, H, v.shape[2],
                               block=spec.block).n_splits
    n_splits = split_geometry(S, spec.block, n_splits)[1]  # effective count
    if n_splits <= 1:
        return etap_decode(q, k, v, length, spec=spec)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    m, l, accT = _partial(q, k, v, length, scale=spec.scale, block=spec.block,
                          n_splits=n_splits, interpret=spec.interpret,
                          fused_dv=0, rescale=spec.rescale)
    return combine_splits(m, l, accT, transposed=True, out_dtype=v.dtype,
                          combine=combine, interpret=spec.interpret,
                          rescale=spec.rescale)


# ------------------------------------------------------------------- paged
def _pad_table(table, multiple: int):
    """Pad the block table to a column multiple with null blocks (id 0);
    padded entries are masked via `lengths` exactly like the dense padded
    tail, so split geometry never repacks the pool."""
    nb = table.shape[1]
    pad = (-nb) % multiple
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    return table


@attn_spec.attn_entry(uses=("interpret", "rescale"))
def etap_decode_paged(q, k_pool, v_pool, table, lengths, *, spec,
                      k_sz=None, v_sz=None):
    """Paged ETAP decode. q: [B,H,Dk]; pools: [N,page,D*]; table:
    [B,max_blocks] int32; lengths: [B]. Returns [B,H,Dv].  Bit-identical
    to :func:`etap_decode` at block == page on the same logical rows.
    k_sz/v_sz: per-row (scale, zp) pools [N,page,2] when the pools hold
    int8/fp8 codes (in-register dequant, DESIGN.md §11)."""
    return etap_decode_paged_pallas(q, k_pool, v_pool, table, lengths,
                                    scale=spec.scale,
                                    interpret=spec.interpret,
                                    k_sz=k_sz, v_sz=v_sz,
                                    rescale=spec.rescale)


@attn_spec.attn_entry(uses=("interpret", "rescale"), static_argnames=("dv",))
def etap_decode_mla_paged(q, kv_pool, dv: int, table, lengths, *, spec,
                          kv_sz=None):
    """Paged MLA-fused ETAP: one latent pool, V = pool[..., :dv]."""
    return etap_decode_mla_paged_pallas(q, kv_pool, dv, table, lengths,
                                        scale=spec.scale,
                                        interpret=spec.interpret,
                                        kv_sz=kv_sz, rescale=spec.rescale)


@attn_spec.attn_entry(uses=("interpret", "rescale"))
def etap_prefill_paged(q, k_pool, v_pool, table, start, *, spec,
                       k_sz=None, v_sz=None):
    """Chunked paged ETAP prefill (separate-V). q: [B,Cq,H,Dk]; pools:
    [N,page,D*]; table: [B,max_blocks] int32; start: [B] tokens already in
    the pool before the chunk (whose rows must already be appended).
    Returns [B,Cq,H,Dv] — causal within the chunk, full over the pool."""
    return etap_prefill_paged_pallas(q, k_pool, v_pool, table, start,
                                     scale=spec.scale,
                                     interpret=spec.interpret,
                                     k_sz=k_sz, v_sz=v_sz,
                                     rescale=spec.rescale)


@attn_spec.attn_entry(uses=("interpret", "rescale"), static_argnames=("dv",))
def etap_prefill_mla_paged(q, kv_pool, dv: int, table, start, *, spec,
                           kv_sz=None):
    """Chunked paged MLA-fused ETAP prefill: one latent pool, V = pool[..., :dv]."""
    return etap_prefill_mla_paged_pallas(q, kv_pool, dv, table, start,
                                         scale=spec.scale,
                                         interpret=spec.interpret,
                                         kv_sz=kv_sz, rescale=spec.rescale)


@attn_spec.attn_entry(uses=("interpret", "rescale"))
def etap_verify_paged(q, k_pool, v_pool, table, start, qpos, *, spec,
                      k_sz=None, v_sz=None):
    """Draft-verify attention over a paged cache (DESIGN.md §14): the
    chunked-prefill kernel with an EXPLICIT per-query position mask.
    q: [B,Cq,H,Dk] — the Cq drafted rows (already appended); qpos: [B,Cq]
    int32 absolute positions; start: [B] rows in the pool before the
    chunk.  Row c attends to pool positions <= qpos[b, c]."""
    return etap_verify_paged_pallas(q, k_pool, v_pool, table, start, qpos,
                                    scale=spec.scale,
                                    interpret=spec.interpret,
                                    k_sz=k_sz, v_sz=v_sz,
                                    rescale=spec.rescale)


@attn_spec.attn_entry(uses=("interpret", "rescale"), static_argnames=("dv",))
def etap_verify_mla_paged(q, kv_pool, dv: int, table, start, qpos, *, spec,
                          kv_sz=None):
    """Paged MLA-fused draft-verify: one latent pool, V = pool[..., :dv],
    explicit per-query positions (see :func:`etap_verify_paged`)."""
    return etap_verify_mla_paged_pallas(q, kv_pool, dv, table, start, qpos,
                                        scale=spec.scale,
                                        interpret=spec.interpret,
                                        kv_sz=kv_sz, rescale=spec.rescale)


def _paged_partial(q, k_pool, v_pool, table, lengths, *, scale, n_splits,
                   interpret, fused_dv, rescale, k_sz=None, v_sz=None):
    n_splits, npb, padded_nb = paged_split_geometry(table.shape[1], n_splits)
    table = _pad_table(table, padded_nb)
    return etap_paged_partial_pallas(q, k_pool, v_pool, table, lengths,
                                     scale=scale, n_splits=n_splits,
                                     interpret=interpret, fused_dv=fused_dv,
                                     k_sz=k_sz, v_sz=v_sz, rescale=rescale)


@attn_spec.attn_entry(uses=("kv_splits", "interpret", "rescale"),
                      static_argnames=("combine",))
def etap_decode_paged_splitkv(q, k_pool, v_pool, table, lengths, *, spec,
                              combine: str = "pallas", k_sz=None, v_sz=None):
    """Two-phase split-KV ETAP decode over a paged cache. spec.kv_splits
    None/0 → auto via the block-granular scheduler; 1 routes to the
    single-pass paged kernel (bit-identical, same argument as the dense
    path).  Requests for more splits than table columns degrade to the
    effective count of the shared geometry (no zero-length splits)."""
    B, H, _ = q.shape
    page = k_pool.shape[1]
    n_splits = int(spec.kv_splits or 0)
    if not n_splits:
        n_splits = plan_splits_paged(B, table.shape[1], page, H,
                                     v_pool.shape[2]).n_splits
    n_splits = paged_split_geometry(table.shape[1], n_splits)[0]
    if n_splits <= 1:
        return etap_decode_paged(q, k_pool, v_pool, table, lengths,
                                 spec=spec, k_sz=k_sz, v_sz=v_sz)
    m, l, accT = _paged_partial(q, k_pool, v_pool, table, lengths,
                                scale=spec.scale, n_splits=n_splits,
                                interpret=spec.interpret, fused_dv=0,
                                k_sz=k_sz, v_sz=v_sz, rescale=spec.rescale)
    out_dtype = q.dtype if k_sz is not None else v_pool.dtype
    return combine_splits(m, l, accT, transposed=True,
                          out_dtype=out_dtype, combine=combine,
                          interpret=spec.interpret, rescale=spec.rescale)


@attn_spec.attn_entry(uses=("kv_splits", "interpret", "rescale"),
                      static_argnames=("dv", "combine"))
def etap_decode_mla_paged_splitkv(q, kv_pool, dv: int, table, lengths, *,
                                  spec, combine: str = "pallas", kv_sz=None):
    """Two-phase split-KV over a paged MLA latent pool (V = pool[..., :dv])."""
    B, H, _ = q.shape
    page = kv_pool.shape[1]
    n_splits = int(spec.kv_splits or 0)
    if not n_splits:
        n_splits = plan_splits_paged(B, table.shape[1], page, H, dv).n_splits
    n_splits = paged_split_geometry(table.shape[1], n_splits)[0]
    if n_splits <= 1:
        return etap_decode_mla_paged(q, kv_pool, dv, table, lengths,
                                     spec=spec, kv_sz=kv_sz)
    m, l, accT = _paged_partial(q, kv_pool, None, table, lengths,
                                scale=spec.scale, n_splits=n_splits,
                                interpret=spec.interpret, fused_dv=dv,
                                k_sz=kv_sz, rescale=spec.rescale)
    out_dtype = q.dtype if kv_sz is not None else kv_pool.dtype
    return combine_splits(m, l, accT, transposed=True,
                          out_dtype=out_dtype, combine=combine,
                          interpret=spec.interpret, rescale=spec.rescale)


@attn_spec.attn_entry(uses=("block", "kv_splits", "interpret", "rescale"),
                      static_argnames=("dv", "combine"))
def etap_decode_mla_splitkv(q, kv, dv: int, length=None, *, spec,
                            combine: str = "pallas"):
    """Two-phase split-KV, MLA-fused single-latent-stream variant."""
    BG, H, _ = q.shape
    S = kv.shape[1]
    n_splits = int(spec.kv_splits or 0)
    if not n_splits:
        n_splits = plan_splits(BG, S, H, dv, block=spec.block).n_splits
    n_splits = split_geometry(S, spec.block, n_splits)[1]  # effective count
    if n_splits <= 1:
        return etap_decode_mla(q, kv, dv, length, spec=spec)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    m, l, accT = _partial(q, kv, None, length, scale=spec.scale,
                          block=spec.block, n_splits=n_splits,
                          interpret=spec.interpret, fused_dv=dv,
                          rescale=spec.rescale)
    return combine_splits(m, l, accT, transposed=True, out_dtype=kv.dtype,
                          combine=combine, interpret=spec.interpret,
                          rescale=spec.rescale)
