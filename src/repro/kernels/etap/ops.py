"""jit'd public wrappers for the ETAP kernel: shape normalization (pad S to a
block multiple — masked via `length`), dtype checks, MLA-fused entry point."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.etap.etap import etap_decode_mla_pallas, etap_decode_pallas


def _pad_seq(x, block: int):
    S = x.shape[1]
    pad = (-S) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


@functools.partial(jax.jit, static_argnames=("scale", "block", "interpret"))
def etap_decode(q, k, v, length=None, *, scale: float, block: int = 512,
                interpret: bool = True):
    """ETAP decode attention. q: [BG,H,Dk]; k: [BG,S,Dk]; v: [BG,S,Dv];
    length: [BG] valid-prefix lengths (None = all S). Returns [BG,H,Dv]."""
    BG, _, _ = q.shape
    S = k.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    k = _pad_seq(k, block)     # padded tail is masked out via `length`
    v = _pad_seq(v, block)
    return etap_decode_pallas(q, k, v, length, scale=scale, block=block,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dv", "scale", "block", "interpret"))
def etap_decode_mla(q, kv, dv: int, length=None, *, scale: float,
                    block: int = 512, interpret: bool = True):
    """MLA-fused ETAP: one latent stream [BG,S,latent]; V = kv[..., :dv]."""
    BG = q.shape[0]
    S = kv.shape[1]
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    block = min(block, S)
    kv = _pad_seq(kv, block)
    return etap_decode_mla_pallas(q, kv, dv, length, scale=scale, block=block,
                                  interpret=interpret)
