"""Split-KV phase 2: merge per-split (m, ℓ, Acc) partial stats into O.

The merge stays in the (m, ℓ, acc) statistic domain (AMLA-style — one
global rescale per split, never a renormalize-then-renormalize chain):

    m* = max_s m_s            w_s = exp(m_s - m*)
    ℓ* = Σ_s w_s ℓ_s          Acc* = Σ_s w_s Acc_s
    O  = epilogue(Acc* / ℓ*)   (transpose for the ETAP orientation)

A fully-masked split carries (m = -1e30, ℓ = 0, Acc = garbage·0-weight);
its weight w_s = exp(-1e30 - m*) underflows to exactly 0, so it drops out
of the merge without a branch — the ``ℓ = 0`` edge case costs nothing.

With a single split the weights are exp(0) = 1 and the merge reduces
bitwise to the single-pass epilogue ``(Acc / ℓ)ᵀ`` — split-KV with
n_splits=1 is bit-compatible with the one-phase kernels.

Two backends: a Pallas kernel (one grid step per batch-group row) and an
XLA fallback reusing :func:`repro.core.etap.combine_partials`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _combine_body(m_ref, l_ref, acc_ref, o_ref, *, transposed: bool):
    # fp32 END-TO-END until the final epilogue cast (DESIGN.md §6/§11):
    # the merge weights are exponentials of stat DIFFERENCES — computing
    # exp(m - m*) or the ℓ/Acc reductions in a half dtype (as a caller
    # handing in downcast stats would make jnp's dtype-following ops do)
    # collapses nearby splits' weights and loses the paper's RMSE edge.
    # The upcast is the guard: only o_ref.dtype may be narrow.
    m = m_ref[0].astype(jnp.float32)                   # [n, H]
    l = l_ref[0].astype(jnp.float32)                   # [n, H]
    acc = acc_ref[0].astype(jnp.float32)               # [n,Dv,H] | [n,H,Dv]
    m_g = jnp.max(m, axis=0, keepdims=True)            # [1, H]
    w = jnp.exp(m - m_g)                               # [n, H]
    l_g = jnp.sum(l * w, axis=0, keepdims=True)        # [1, H]
    if transposed:                                     # ETAP: epilogue (·)ᵀ
        acc_g = jnp.sum(acc * w[:, None, :], axis=0)   # [Dv, H]
        o_ref[0] = (acc_g / l_g).T.astype(o_ref.dtype)
    else:                                              # standard orientation
        acc_g = jnp.sum(acc * w[:, :, None], axis=0)   # [H, Dv]
        o_ref[0] = (acc_g / l_g.T).astype(o_ref.dtype)


def combine_splits_pallas(m, l, acc, *, transposed: bool, out_dtype,
                          interpret: bool = True):
    """m, l: [BG,n,H]; acc: [BG,n,Dv,H] (transposed) or [BG,n,H,Dv].
    Returns O: [BG,H,Dv]."""
    BG, n, H = m.shape
    Dv = acc.shape[2] if transposed else acc.shape[3]
    acc_blk = (1, n, Dv, H) if transposed else (1, n, H, Dv)
    return pl.pallas_call(
        functools.partial(_combine_body, transposed=transposed),
        grid=(BG,),
        in_specs=[
            pl.BlockSpec((1, n, H), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n, H), lambda b: (b, 0, 0)),
            pl.BlockSpec(acc_blk, lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, Dv), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BG, H, Dv), out_dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(m, l, acc)


def combine_splits_xla(m, l, acc, *, transposed: bool, out_dtype):
    """XLA fallback (identical math; used when the combine kernel is not
    worth a launch, e.g. under vmap or on non-TPU backends).  Same fp32
    end-to-end contract as the Pallas body: stats are upcast on entry and
    only the final O is cast to `out_dtype`."""
    m = m.astype(jnp.float32)
    l = l.astype(jnp.float32)
    acc = acc.astype(jnp.float32)
    if transposed:
        from repro.core.etap import combine_partials
        o = combine_partials(jnp.moveaxis(m, 1, 0), jnp.moveaxis(l, 1, 0),
                             jnp.moveaxis(acc, 1, 0))
        return o.astype(out_dtype)
    m_g = jnp.max(m, axis=1, keepdims=True)            # [BG,1,H]
    w = jnp.exp(m - m_g)                               # [BG,n,H]
    l_g = jnp.sum(l * w, axis=1)                       # [BG,H]
    acc_g = jnp.sum(acc * w[..., None], axis=1)        # [BG,H,Dv]
    return (acc_g / l_g[..., None]).astype(out_dtype)


def combine_splits(m, l, acc, *, transposed: bool, out_dtype,
                   combine: str = "pallas", interpret: bool = True):
    """Dispatch phase-2 merge: combine = "pallas" | "xla"."""
    if combine == "xla":
        return combine_splits_xla(m, l, acc, transposed=transposed,
                                  out_dtype=out_dtype)
    return combine_splits_pallas(m, l, acc, transposed=transposed,
                                 out_dtype=out_dtype, interpret=interpret)
