"""Split-KV phase 2: merge per-split (m, ℓ, Acc) partial stats into O.

The merge math — global max, per-split weights, weighted ℓ/Acc sums — is
:func:`repro.kernels.softmax_state.merge_splits`, the ONE stat-domain merge
definition shared with the sequence-sharded XLA combine in
``core/etap.py`` (they were two hand-synced copies before DESIGN.md §13).
``rescale`` must match the mode the partials were produced under: the stats
live in that mode's domain (natural-log max vs power-of-two bias).

A fully-masked split carries (m = -1e30, ℓ = 0, Acc = garbage·0-weight);
its weight underflows to exactly 0, so it drops out of the merge without a
branch.  With a single split the weights are exp(0) = 1 (amla: 2^0 = 1)
and the merge reduces bitwise to the single-pass epilogue ``(Acc / ℓ)ᵀ`` —
split-KV with n_splits=1 is bit-compatible with the one-phase kernels.

fp32 end-to-end until the final epilogue cast (DESIGN.md §6/§11): the
merge weights are exponentials of stat DIFFERENCES — computing them in a
half dtype collapses nearby splits' weights and loses the paper's RMSE
edge.  The upcast guard lives inside ``merge_splits`` itself (the PR 5
bf16-stat bug can't be reintroduced from a call site); only o_ref.dtype
may be narrow.

Two backends: a Pallas kernel (one grid step per batch-group row) and an
XLA fallback tracing the same merge under plain jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.kernels import softmax_state


def _combine_body(m_ref, l_ref, acc_ref, o_ref, *, transposed: bool,
                  rescale: str):
    if transposed:                                     # ETAP: acc [n, Dv, H]
        _, l_g, acc_g = softmax_state.merge_splits(
            m_ref[0], l_ref[0], acc_ref[0], axis=0, mode=rescale,
            expand=lambda w: w[:, None, :])
        o_ref[0] = (acc_g / l_g).T.astype(o_ref.dtype)     # [H, Dv]
    else:                                              # standard: [n, H, Dv]
        _, l_g, acc_g = softmax_state.merge_splits(
            m_ref[0], l_ref[0], acc_ref[0], axis=0, mode=rescale,
            expand=lambda w: w[:, :, None])
        o_ref[0] = (acc_g / l_g[:, None]).astype(o_ref.dtype)


def combine_splits_pallas(m, l, acc, *, transposed: bool, out_dtype,
                          interpret: bool = True,
                          rescale: str | None = None):
    """m, l: [BG,n,H]; acc: [BG,n,Dv,H] (transposed) or [BG,n,H,Dv].
    Returns O: [BG,H,Dv]."""
    BG, n, H = m.shape
    Dv = acc.shape[2] if transposed else acc.shape[3]
    acc_blk = (1, n, Dv, H) if transposed else (1, n, H, Dv)
    return pl.pallas_call(
        functools.partial(_combine_body, transposed=transposed,
                          rescale=softmax_state.resolve(rescale)),
        grid=(BG,),
        in_specs=[
            pl.BlockSpec((1, n, H), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n, H), lambda b: (b, 0, 0)),
            pl.BlockSpec(acc_blk, lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, Dv), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BG, H, Dv), out_dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(m, l, acc)


def combine_splits_xla(m, l, acc, *, transposed: bool, out_dtype,
                       rescale: str | None = None):
    """XLA fallback (identical math; used when the combine kernel is not
    worth a launch, e.g. under vmap or on non-TPU backends)."""
    mode = softmax_state.resolve(rescale)
    if transposed:                                     # acc [BG,n,Dv,H]
        _, l_g, acc_g = softmax_state.merge_splits(
            m, l, acc, axis=1, mode=mode,
            expand=lambda w: w[:, :, None, :])
        return jnp.moveaxis(acc_g / l_g[:, None, :], 1, 2).astype(out_dtype)
    _, l_g, acc_g = softmax_state.merge_splits(       # acc [BG,n,H,Dv]
        m, l, acc, axis=1, mode=mode,
        expand=lambda w: w[..., None])
    return (acc_g / l_g[..., None]).astype(out_dtype)


def combine_splits(m, l, acc, *, transposed: bool, out_dtype,
                   combine: str = "pallas", interpret: bool = True,
                   rescale: str | None = None):
    """Dispatch phase-2 merge: combine = "pallas" | "xla"."""
    if combine == "xla":
        return combine_splits_xla(m, l, acc, transposed=transposed,
                                  out_dtype=out_dtype, rescale=rescale)
    return combine_splits_pallas(m, l, acc, transposed=transposed,
                                 out_dtype=out_dtype, interpret=interpret,
                                 rescale=rescale)
