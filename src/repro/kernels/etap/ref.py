"""Pure-jnp oracle for ETAP decode attention (and its fp64 variant for the
paper's Table-1 RMSE study). No blocking, no online softmax — the direct
mathematical definition, written in the *transposed* (ETAP) orientation so
the kernel's algebra can be checked step by step:

    Sᵀ = K Qᵀ          [S, H]
    Pᵀ = softmax_cols(Sᵀ)
    Oᵀ = Vᵀ Pᵀ          [Dv, H]
    O  = (Oᵀ)ᵀ          [H, Dv]

which is elementwise identical to softmax_rows(Q Kᵀ) V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def etap_decode_ref(q, k, v, length=None, *, scale: float, dtype=jnp.float32):
    """q: [BG,H,Dk]; k: [BG,S,Dk]; v: [BG,S,Dv]; length: [BG] or None.
    Computes in `dtype` (float64 for the RMSE oracle) and returns [BG,H,Dv]."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    qf, kf, vf = (a.astype(dtype) for a in (q, k, v))
    sT = jnp.einsum("bsd,bhd->bsh", kf, qf) * dtype(scale)    # Sᵀ = K Qᵀ
    if length is not None:
        pos = jnp.arange(S)
        sT = jnp.where((pos[None, :] < length[:, None])[:, :, None], sT,
                       dtype(-jnp.inf))
    pT = jax.nn.softmax(sT, axis=1)                           # softmax over S (cols)
    oT = jnp.einsum("bsv,bsh->bvh", vf, pT)                   # Oᵀ = Vᵀ Pᵀ
    return jnp.swapaxes(oT, 1, 2).astype(v.dtype)             # O = (Oᵀ)ᵀ


def etap_decode_state_ref(q, k, v, length=None, *, scale: float,
                          rescale: str | None = None):
    """Blockless degenerate of the softmax-state API: one ``init``, ONE
    ``update`` over the whole context, ``finalize``.  With a single block
    there is nothing to rescale (corr multiplies the zero-initialised
    accumulator), so both modes agree with :func:`etap_decode_ref` up to
    the exp-domain change — this is the anchor the state-API tests use to
    pin ``update``'s recurrence against the direct definition."""
    from repro.kernels import softmax_state
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    mode = softmax_state.resolve(rescale)
    sT = jnp.einsum("bsd,bhd->bsh", k.astype(jnp.float32),
                    q.astype(jnp.float32)) * scale            # [BG, S, H]
    if length is not None:
        pos = jnp.arange(S)
        sT = jnp.where((pos[None, :] < length[:, None])[:, :, None], sT,
                       softmax_state.NEG_INF)
    vf = v.astype(jnp.float32)
    state = softmax_state.init((BG, H), (BG, Dv, H))
    state = softmax_state.update(
        state, sT, lambda p: jnp.einsum("bsv,bsh->bvh", vf, p),
        axis=1, mode=mode, expand=lambda c: c[:, None, :])
    oT = softmax_state.finalize(state, expand=lambda l: l[:, None, :])
    return jnp.swapaxes(oT, 1, 2).astype(v.dtype)


# ------------------------------------------------------ quantized twins
def dequantize(codes, sz):
    """Reference dequant for quantized KV (DESIGN.md §11): codes [..., F]
    + per-row (scale, zp) [..., 2] -> fp32 rows.  Delegates to the runtime
    definition so the kernel (kernels/etap/etap.py:_dequant), the XLA
    gather path (core/etap.py), and this oracle can never drift apart."""
    from repro.runtime.paged_cache import dequantize_rows
    return dequantize_rows(codes, sz)


def etap_decode_quant_ref(q, k_codes, k_sz, v_codes, v_sz, length=None, *,
                          scale: float, dv: int = 0, dtype=jnp.float32):
    """Oracle for the quantized decode kernels: dequantize densely, then
    the direct (unblocked) transposed softmax.  v_codes None -> MLA-fused
    (V = the first `dv` dequantized latent columns, exactly the kernels'
    dequant-then-slice order).  Shapes as :func:`etap_decode_ref` with
    codes in place of fp K/V."""
    k = dequantize(k_codes, k_sz)
    v = dequantize(v_codes, v_sz) if v_codes is not None else k[..., :dv]
    return etap_decode_ref(q, k, v, length, scale=scale, dtype=dtype)
