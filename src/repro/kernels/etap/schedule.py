"""Auto-tuned KV-split scheduler for two-phase ETAP decode (DESIGN.md §3).

Mirrors FlashMLA's ``num_splits`` logic: decode launches one work item per
(batch-group, split); at small batch × long context the single-split grid
leaves almost every core idle, so the context is cut until the grid fills
the machine — but never so far that (a) a split owns too few KV blocks to
amortize its prologue/epilogue, or (b) the per-split (m, ℓ, Accᵀ) stat
traffic that phase 2 re-reads stops being negligible next to the one
mandatory streaming of the KV cache (the roofline term the paper's workload
is bound by — see launch/roofline.py:splitkv_roofline).

All three caps are monotone non-decreasing in S with everything else fixed,
so the chosen split count grows monotonically with context length and is 1
for short contexts / large batches — where the single-pass kernel is already
occupancy-bound and split-KV would only add combine overhead.
"""
from __future__ import annotations

import dataclasses

# Parallel compute units to occupy. TPU decode work items are distributed at
# core granularity (v5e: 1 TensorCore/chip, but the grid also feeds the
# 8-way megacore/sparsecore pipelining; H20 in the paper: 78 SMs). The
# constant is deliberately conservative — doubling it only matters once
# BG * n_splits exceeds it.
DEFAULT_CORES = 8
WAVE_FACTOR = 2            # aim for this many work items per core
MIN_BLOCKS_PER_SPLIT = 2   # a split must own >= this many KV blocks
STATS_TRAFFIC_BUDGET = 8   # stat bytes must stay under kv_bytes / this


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Chosen split-KV launch geometry."""
    n_splits: int
    block: int
    nb_per_split: int          # KV blocks each split walks (after padding)

    @property
    def padded_s(self) -> int:
        return self.n_splits * self.nb_per_split * self.block


def _floor_pow2(n: int) -> int:
    return 1 << (max(n, 1).bit_length() - 1)


def split_geometry(S: int, block: int, n_splits: int):
    """Canonical launch geometry for cutting an S-long context into at most
    n_splits segments: (block, n_splits, nb_per_split, padded_s).  Every
    split-KV entry point (Pallas wrappers, XLA path) pads S to `padded_s`
    with this ONE function so the phase-1 kernels' S % (n·npb·block) == 0
    contract can never diverge between paths.

    The returned split count is EFFECTIVE: requests with n_splits > nb (or
    S < block, which collapses to nb == 1) degrade to the largest count
    where every split still owns >= 1 real KV block — zero-length splits
    would each burn a grid row computing a fully-masked block whose stats
    are discarded by the combine, and at n_splits > nb the phase-2 stat
    traffic could exceed the KV bytes the split was meant to amortize.
    Callers MUST launch with the returned count, not the requested one."""
    S = max(int(S), 1)
    block = max(1, min(block, S))
    nb = -(-S // block)
    n_splits = max(1, min(int(n_splits), nb))
    npb = -(-nb // n_splits)
    n_splits = -(-nb // npb)       # drop splits starting past the last block
    return block, n_splits, npb, n_splits * npb * block


def paged_split_geometry(nb: int, n_splits: int):
    """Split geometry over a PAGED cache: the atomic unit is one KV page
    (block-table entry), so splits always land on page boundaries.
    Returns (n_splits, nb_per_split, padded_nb) — n_splits EFFECTIVE,
    clamped exactly like :func:`split_geometry` (no split may own only
    padding); callers pad the block table to `padded_nb` columns with null
    blocks (masked via lengths) and launch with the returned count."""
    nb = max(int(nb), 1)
    n_splits = max(1, min(int(n_splits), nb))
    npb = -(-nb // n_splits)
    n_splits = -(-nb // npb)       # drop splits starting past the last block
    return n_splits, npb, n_splits * npb


def plan_splits_paged(B: int, nb: int, page: int, H: int, Dv: int, *,
                      num_cores: int = DEFAULT_CORES,
                      kv_itemsize: int = 2) -> SplitPlan:
    """Block-granular split plan for a paged decode: same occupancy /
    granularity / stat-traffic caps as :func:`plan_splits` with the KV
    block pinned to the page size (the paged kernels can only cut the
    context where the block table cuts it), so the chosen ``n_splits``
    composes with paging without repacking the pool."""
    plan = plan_splits(B, max(int(nb), 1) * page, H, Dv, block=page,
                       num_cores=num_cores, kv_itemsize=kv_itemsize)
    n_eff, npb, _ = paged_split_geometry(nb, plan.n_splits)
    return SplitPlan(n_splits=n_eff, block=page, nb_per_split=npb)


def plan_splits(BG: int, S: int, H: int, Dv: int, *, block: int = 512,
                num_cores: int = DEFAULT_CORES,
                kv_itemsize: int = 2) -> SplitPlan:
    """Pick (n_splits, block) for a decode of shape (BG, S, H, Dv).

    occupancy: want BG * n_splits >= WAVE_FACTOR * num_cores
    granularity: each split keeps >= MIN_BLOCKS_PER_SPLIT KV blocks
    traffic: n_splits * stat_bytes <= kv_bytes / STATS_TRAFFIC_BUDGET
    """
    S = max(int(S), 1)
    block = max(1, min(block, S))
    nb = -(-S // block)
    want = -(-WAVE_FACTOR * num_cores // max(int(BG), 1))
    cap_blocks = max(1, nb // MIN_BLOCKS_PER_SPLIT)
    # per-split phase-2 payload: fp32 (m, ℓ) [2·H] + Accᵀ [Dv·H]
    stat_bytes = 4 * H * (Dv + 2)
    kv_bytes = 2 * S * Dv * kv_itemsize        # K + V streams (≈; MLA: one)
    cap_traffic = max(1, kv_bytes // (STATS_TRAFFIC_BUDGET * stat_bytes))
    n = _floor_pow2(min(want, cap_blocks, int(cap_traffic)))
    npb = -(-nb // n)
    return SplitPlan(n_splits=n, block=block, nb_per_split=npb)
