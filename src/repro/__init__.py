"""repro — FlashMLA-ETAP reproduction package.

Importing the package installs the JAX version-compat shims (see
``repro.compat``): tests and launch scripts written against the newer mesh
APIs (``jax.set_mesh``, ``jax.sharding.AxisType``, ...) then run unmodified
on older installed JAX.

When JAX is not installed the shims are skipped instead of failing the
import: the pure-stdlib subpackages (``repro.analysis`` — the CI lint job
runs it in a ruff-only environment with no JAX wheel) stay importable.
"""
try:
    from repro import compat as _compat
except ModuleNotFoundError as _e:
    if _e.name not in ("jax", "jaxlib"):
        raise
else:
    _compat.install()
