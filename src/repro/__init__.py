"""repro — FlashMLA-ETAP reproduction package.

Importing the package installs the JAX version-compat shims (see
``repro.compat``): tests and launch scripts written against the newer mesh
APIs (``jax.set_mesh``, ``jax.sharding.AxisType``, ...) then run unmodified
on older installed JAX.
"""
from repro import compat as _compat

_compat.install()
