"""Baseline file: grandfathered findings, keyed by content fingerprint.

A baseline entry is one line::

    <fingerprint>  <rule>  <path>:<line>  # one-line justification

Only the fingerprint (sha1 of path|rule|flagged-line-content, see
:class:`repro.analysis.core.Finding`) is matched — the trailing fields
are for the human reading the file, and the justification comment is
REQUIRED by policy (DESIGN.md §16): a grandfathered violation without a
why is just a violation.  Entries go stale when the flagged line is
edited or removed; stale entries are reported (so the file shrinks over
time) but do not fail the run.
"""
from __future__ import annotations

import pathlib

DEFAULT_NAME = "analysis_baseline.txt"

_HEADER = """\
# repro.analysis baseline — grandfathered findings (DESIGN.md §16).
# One line per finding:  <fingerprint>  <rule>  <path>:<line>  # why
# Regenerate with:  python -m repro.analysis --write-baseline
# Policy: every entry carries a one-line justification; new code never
# adds entries — fix the finding or suppress the single line with
# `# noqa: REPRO0xx` and a reason.
"""


def load(path: pathlib.Path) -> set[str]:
    """Fingerprints grandfathered by ``path`` (missing file = empty)."""
    if not path.is_file():
        return set()
    fps: set[str] = set()
    for raw in path.read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        fps.add(line.split()[0])
    return fps


def split(findings, fps):
    """Partition ``findings`` into (kept, baselined) and report stale
    baseline fingerprints that matched nothing."""
    kept, baselined, seen = [], [], set()
    for f in findings:
        if f.fingerprint in fps:
            baselined.append(f)
            seen.add(f.fingerprint)
        else:
            kept.append(f)
    stale = sorted(fps - seen)
    return kept, baselined, stale


def write(path: pathlib.Path, findings) -> None:
    lines = [_HEADER]
    for f in sorted(findings, key=lambda f: (f.rel, f.line, f.rule)):
        lines.append(f"{f.fingerprint}  {f.rule}  {f.rel}:{f.line}"
                     f"  # TODO: justify or fix")
    path.write_text("\n".join(lines) + "\n")
