"""repro.analysis — unified static invariant analyzer (DESIGN.md §16).

Five passes over the tree's ASTs, each encoding a bug this codebase has
actually shipped or structurally prevents:

    dtype-flow   REPRO001/002  sub-fp32 softmax stats; hand-rolled rescale
    retrace      REPRO003–006  stale-trace hazards around jax.jit/AttnSpec
    pool-api     REPRO007      BlockPool/PrefixCache private-state touches
    donation     REPRO008      use-after-donate of jitted buffers
    bare-print   REPRO009      runtime stats escaping the telemetry registry

Run ``python -m repro.analysis`` (stdlib-only — the CI lint job runs it
with no JAX installed); suppress a single line with ``# noqa: REPRO0xx``;
grandfathered findings live in ``analysis_baseline.txt``.
"""
from repro.analysis.baseline import DEFAULT_NAME
from repro.analysis.cli import ALL_RULES, PASSES, main, run_passes
from repro.analysis.core import Finding, Rule, SourceFile
