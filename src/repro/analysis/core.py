"""Shared infrastructure for the repro.analysis passes (DESIGN.md §16).

One :class:`SourceFile` per scanned file (text + lazily parsed AST), one
:class:`Finding` per rule hit (repo-relative path, line, rule id, message,
and the stripped source line — the line text, not the line NUMBER, feeds
the fingerprint, so baselined findings survive unrelated edits above
them).  Suppression is per-line and per-rule: ``# noqa: REPRO0xx`` on the
flagged line silences exactly that rule (a bare ``# noqa`` does NOT — a
suppression must say which invariant it is waiving).

Everything in this package is stdlib-only: the CI lint job runs the
analyzer in a ruff-only environment with no JAX/numpy installed, exactly
like the three ``benchmarks/lint_*.py`` scripts it replaced.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import pathlib
import re

# repo root when running from a source checkout: src/repro/analysis/ -> repo
REPO = pathlib.Path(__file__).resolve().parents[3]

# directories the full run walks, repo-relative; per-rule scoping inside the
# pass modules narrows further (e.g. dtype-flow only reads kernels/)
SCAN_ROOTS = ("src/repro", "benchmarks", "tests", "examples")
# the fixture corpus is INTENTIONALLY full of violations
EXCLUDE_PREFIXES = ("tests/analysis_fixtures",)

_NOQA = re.compile(r"#\s*noqa:\s*(?P<codes>[A-Z][A-Z0-9 ,]*)", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Rule:
    """Catalog entry: the id is what suppressions and the baseline key on;
    ``rationale`` names the historical bug the rule encodes."""
    id: str
    name: str
    summary: str
    rationale: str


@dataclasses.dataclass(frozen=True)
class Finding:
    rel: str        # repo-relative posix path
    line: int       # 1-indexed
    rule: str       # "REPRO0xx"
    message: str
    source: str = ""   # stripped text of the flagged line
    severity: str = "error"

    @property
    def fingerprint(self) -> str:
        """Stable id for baselining: path + rule + line CONTENT (not line
        number), so entries survive edits elsewhere in the file."""
        blob = f"{self.rel}|{self.rule}|{self.source.strip()}"
        return hashlib.sha1(blob.encode()).hexdigest()[:12]

    def render(self) -> str:
        return f"{self.rel}:{self.line}: {self.rule} {self.message}"


class SourceFile:
    """One scanned file: text, lines, lazily parsed AST, suppression map."""

    def __init__(self, rel: str, text: str):
        self.rel = rel.replace("\\", "/")
        self.text = text
        self.lines = text.splitlines()
        self._tree: ast.Module | None = None
        self.parse_error: SyntaxError | None = None

    @property
    def tree(self) -> ast.Module | None:
        if self._tree is None and self.parse_error is None:
            try:
                self._tree = ast.parse(self.text, filename=self.rel)
            except SyntaxError as e:          # surfaced as a REPRO000 finding
                self.parse_error = e
        return self._tree

    def line(self, lineno: int) -> str:
        if 0 < lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def finding(self, node: ast.AST | int, rule: str, message: str) -> Finding:
        lineno = node if isinstance(node, int) else getattr(node, "lineno", 1)
        return Finding(rel=self.rel, line=lineno, rule=rule, message=message,
                       source=self.line(lineno).strip())

    def suppressed(self, f: Finding) -> bool:
        m = _NOQA.search(self.line(f.line))
        if not m:
            return False
        codes = {c.strip().upper() for c in m.group("codes").split(",")}
        return f.rule.upper() in codes


def walk_scope(fn: ast.AST):
    """Yield every node under ``fn`` WITHOUT descending into nested
    function/class scopes (their bodies are analyzed on their own).
    Lambda bodies are kept: they cannot assign, so they share the
    enclosing scope's dataflow."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def functions_of(tree: ast.Module):
    """Every function definition in the module, including nested ones and
    methods — each is analyzed as its own scope."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def dotted_name(node: ast.AST) -> str:
    """``jax.jit`` -> "jax.jit"; non-name chains -> "" (best-effort)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def iter_source_files(root: pathlib.Path, only: set[str] | None = None):
    """Yield :class:`SourceFile` for every .py under the scan roots that
    exist below ``root`` (missing roots are skipped so the analyzer also
    runs on partial trees, e.g. the self-test's temp copy of kernels/).
    ``only`` restricts to an explicit set of repo-relative posix paths —
    the ``--diff`` / positional-paths mode."""
    seen: set[str] = set()
    for sub in SCAN_ROOTS:
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel in seen or "__pycache__" in rel:
                continue
            if any(rel.startswith(p) for p in EXCLUDE_PREFIXES):
                continue
            if only is not None and rel not in only:
                continue
            seen.add(rel)
            yield SourceFile(rel, path.read_text())
