"""retrace-hazard pass: jit traces must not depend on ambient state.

REPRO003 — a jitted function (``jax.jit``, ``@attn_entry``,
``@jit_with_rescale``) that reads a MUTABLE module-level global (dict /
list / set / deque / ...) or declares ``global``.  The global's value is
baked into the trace at first call; mutating it later silently serves the
stale trace.  This is exactly the bug class ``jit_with_rescale`` was
built to kill: the process-default rescale mode is resolved BEFORE the
jit-cache lookup so flipping it can never serve a stale trace.

REPRO004 — an ``@attn_entry(uses=...)`` entry whose body reads a spec
field NOT declared in its ``uses`` tuple.  ``canonicalize`` projects the
spec onto ``uses`` before keying the jit cache (DESIGN.md §14), so an
undeclared field is reset to its default before the trace ever sees it —
the entry silently runs the default no matter what the caller set.

REPRO005 — an unhashable literal (list/dict/set/comprehension) passed as
a static argument of a jitted callable.  jax raises at call time, but
only on the paths that actually execute; the analyzer catches the dead
branches too.

REPRO006 — a function signature outside ``core/attn_spec.py`` declaring
BOTH ``mode=`` and ``rescale=``: a re-introduced pre-AttnSpec keyword-soup
attention entry.  Ported from ``benchmarks/lint_attn_spec.py``.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Rule, SourceFile, dotted_name, functions_of,
                                 walk_scope)

RULES = (
    Rule("REPRO003", "retrace-mutable-global",
         "jitted function closes over mutable module/global state",
         "a traced read of a module-level dict/list bakes the value into "
         "the compiled function; later mutation serves a stale trace "
         "(the bug class jit_with_rescale's pre-cache resolution kills)"),
    Rule("REPRO004", "attn-spec-uses",
         "attn_entry reads a spec field not declared in its uses= tuple",
         "canonicalize() projects the spec onto uses= before the jit key "
         "(DESIGN.md §14); an undeclared field is silently reset to its "
         "default before the trace sees it"),
    Rule("REPRO005", "unhashable-static",
         "unhashable literal passed as a static jit argument",
         "static args key the jit cache and must hash; a list/dict/set "
         "raises at call time — and only on the paths that run"),
    Rule("REPRO006", "attn-spec-signature",
         "function declares both mode= and rescale= (pre-AttnSpec entry)",
         "pre-§14 every attention entry grew the same six keywords and "
         "call sites drifted; the one true bundle is core/attn_spec.py"),
)

_SCOPE = ("src/repro/", "benchmarks/")
_ATTN_SPEC_MODULE = "src/repro/core/attn_spec.py"

# kept in sync with core/attn_spec.AttnSpec (tests/test_analysis.py pins
# this list against dataclasses.fields(AttnSpec) — the analyzer itself
# must not import jax)
SPEC_FIELDS = ("scale", "mode", "rescale", "kv_splits", "kv_dtype", "block",
               "use_kernels", "interpret", "spec_tokens", "spec_draft")
# fields every entry may read: scale is always kept by project()
_ALWAYS_KEPT = {"scale"}

_MUTABLE_CTORS = {"dict", "list", "set", "defaultdict", "deque",
                  "OrderedDict", "Counter"}
_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp, ast.GeneratorExp)
_JIT_NAMES = {"jax.jit", "jit", "jit_with_rescale",
              "softmax_state.jit_with_rescale"}


def _is_jit_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func)
    if name in _JIT_NAMES or name.endswith(".jit"):
        return True
    # functools.partial(jax.jit, ...)
    if name.endswith("partial") and node.args:
        return dotted_name(node.args[0]).endswith("jit")
    return False


def _jit_decorated(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        name = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if (name in _JIT_NAMES or name.endswith(".jit")
                or name.endswith("jit_with_rescale")
                or name.endswith("attn_entry")):
            return True
        if isinstance(dec, ast.Call) and _is_jit_call(dec):
            return True
    return False


def _mutable_globals(tree: ast.Module) -> set[str]:
    """Module-level names bound to a mutable container."""
    out: set[str] = set()
    for node in tree.body:
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        value = node.value
        if value is None:
            continue
        mutable = isinstance(value, (ast.Dict, ast.List, ast.Set,
                                     ast.ListComp, ast.DictComp, ast.SetComp))
        if (isinstance(value, ast.Call)
                and dotted_name(value.func).split(".")[-1] in _MUTABLE_CTORS):
            mutable = True
        if not mutable:
            continue
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for t in targets:
            if isinstance(t, ast.Name):
                out.add(t.id)
    return out


def _local_names(fn: ast.AST) -> set[str]:
    """Names bound inside the function: params, assignments, loop targets,
    withitems, comprehension targets — anything shadowing a global."""
    names: set[str] = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in (args.posonlyargs + args.args + args.kwonlyargs
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            names.add(a.arg)
    for node in walk_scope(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.comprehension):
            names.update(n.id for n in ast.walk(node.target)
                         if isinstance(n, ast.Name))
    return names


def _check_mutable_closure(sf: SourceFile, fn: ast.AST, mutable: set[str],
                           out: list) -> None:
    locals_ = _local_names(fn)
    flagged: set[str] = set()
    for node in walk_scope(fn):
        if isinstance(node, ast.Global):
            out.append(sf.finding(
                node, "REPRO003",
                f"jitted function `{getattr(fn, 'name', '<lambda>')}` "
                f"declares `global` — traced writes to module state are a "
                f"retrace/staleness hazard (DESIGN.md §14)"))
        if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id in mutable and node.id not in locals_
                and node.id not in flagged):
            flagged.add(node.id)
            out.append(sf.finding(
                node, "REPRO003",
                f"jitted function `{getattr(fn, 'name', '<lambda>')}` reads "
                f"mutable module-level `{node.id}` — its value is baked "
                f"into the trace; pass it as an argument or resolve it "
                f"before the jit-cache lookup (DESIGN.md §14)"))


def _attn_entry_uses(fn: ast.FunctionDef | ast.AsyncFunctionDef):
    """The uses= tuple of an @attn_entry decorator, or None."""
    for dec in fn.decorator_list:
        if not (isinstance(dec, ast.Call)
                and dotted_name(dec.func).endswith("attn_entry")):
            continue
        for kw in dec.keywords:
            if kw.arg == "uses" and isinstance(kw.value, (ast.Tuple, ast.List)):
                return {e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)}
        return set()
    return None


def _check_uses(sf: SourceFile, fn, out: list) -> None:
    uses = _attn_entry_uses(fn)
    if uses is None:
        return
    allowed = uses | _ALWAYS_KEPT
    # first occurrence per field (walk_scope order is not source order)
    hits: dict[str, ast.Attribute] = {}
    for node in walk_scope(fn):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "spec"
                and node.attr in SPEC_FIELDS
                and node.attr not in allowed):
            prev = hits.get(node.attr)
            if prev is None or ((node.lineno, node.col_offset)
                                < (prev.lineno, prev.col_offset)):
                hits[node.attr] = node
    for _, node in sorted(hits.items(), key=lambda kv: kv[1].lineno):
        out.append(sf.finding(
            node, "REPRO004",
            f"entry `{fn.name}` reads spec.{node.attr} but its "
            f"attn_entry uses= tuple does not declare it — "
            f"canonicalize() resets the field to its default before "
            f"the trace sees it (DESIGN.md §14)"))


def _static_spec(call: ast.Call):
    """(static_argnames, static_argnums) declared on a jax.jit call."""
    names: set[str] = set()
    nums: set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = (kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List))
                    else [kw.value])
            names |= {v.value for v in vals
                      if isinstance(v, ast.Constant) and isinstance(v.value, str)}
        elif kw.arg == "static_argnums":
            vals = (kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List))
                    else [kw.value])
            nums |= {v.value for v in vals
                     if isinstance(v, ast.Constant) and isinstance(v.value, int)}
    return names, nums


def _jit_aliases(scope: ast.AST) -> dict[str, tuple[set[str], set[int]]]:
    """`g = jax.jit(f, static_arg...)` bindings made directly in ``scope``."""
    aliases: dict[str, tuple[set[str], set[int]]] = {}
    for node in walk_scope(scope):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and _is_jit_call(node.value)):
            names, nums = _static_spec(node.value)
            if names or nums:
                aliases[node.targets[0].id] = (names, nums)
    return aliases


def _check_static_args(sf: SourceFile, scope: ast.AST,
                       aliases: dict[str, tuple[set[str], set[int]]],
                       out: list) -> None:
    """Flag calls in ``scope`` to a known jit alias passing an unhashable
    literal in a static position.  ``aliases`` carries the module-level
    bindings down into function scopes (the common layout: the alias is
    built once at import, the call sites live inside functions)."""
    if not aliases:
        return
    for node in walk_scope(scope):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in aliases):
            continue
        names, nums = aliases[node.func.id]
        for kw in node.keywords:
            if kw.arg in names and isinstance(kw.value, _UNHASHABLE):
                out.append(sf.finding(
                    kw.value, "REPRO005",
                    f"unhashable literal passed as static arg "
                    f"`{kw.arg}` of jitted `{node.func.id}` — static args "
                    f"key the jit cache and must hash"))
        for i, arg in enumerate(node.args):
            if i in nums and isinstance(arg, _UNHASHABLE):
                out.append(sf.finding(
                    arg, "REPRO005",
                    f"unhashable literal passed as static arg {i} of "
                    f"jitted `{node.func.id}` — static args key the jit "
                    f"cache and must hash"))


def _param_names(fn) -> set[str]:
    a = fn.args
    return {p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)}


def run(sf: SourceFile) -> list:
    out: list = []
    if not sf.rel.startswith(_SCOPE) or sf.tree is None:
        return out
    mutable = _mutable_globals(sf.tree)

    # jitted scopes: decorated defs + jax.jit(<fn or lambda>) args
    jitted: list[ast.AST] = []
    for fn in functions_of(sf.tree):
        if _jit_decorated(fn):
            jitted.append(fn)
    defs = {fn.name: fn for fn in functions_of(sf.tree)}
    seen = set(map(id, jitted))
    for node in ast.walk(sf.tree):
        if not _is_jit_call(node):
            continue
        if node.args:
            target = node.args[0]
            if isinstance(target, ast.Lambda) and id(target) not in seen:
                jitted.append(target)
                seen.add(id(target))
            elif (isinstance(target, ast.Name) and target.id in defs
                    and id(defs[target.id]) not in seen):
                jitted.append(defs[target.id])
                seen.add(id(defs[target.id]))
    if mutable:
        for fn in jitted:
            _check_mutable_closure(sf, fn, mutable, out)

    for fn in functions_of(sf.tree):
        _check_uses(sf, fn, out)
        if (sf.rel != _ATTN_SPEC_MODULE
                and {"mode", "rescale"} <= _param_names(fn)):
            out.append(sf.finding(
                fn, "REPRO006",
                f"function `{fn.name}` declares both `mode=` and "
                f"`rescale=` — a pre-AttnSpec attention entry point; take "
                f"a single `spec: AttnSpec` instead (core/attn_spec.py, "
                f"DESIGN.md §14)"))

    module_aliases = _jit_aliases(sf.tree)
    _check_static_args(sf, sf.tree, module_aliases, out)
    for fn in functions_of(sf.tree):
        _check_static_args(sf, fn, {**module_aliases, **_jit_aliases(fn)},
                           out)
    return out
