"""bare-print pass: runtime/serving numbers flow through telemetry.

REPRO009 — a bare ``print(...)`` in ``src/repro/runtime/`` or the serve
loop.  The observability layer (DESIGN.md §15) exists so every number the
serving stack emits flows through ONE snapshot: counters/gauges/
histograms land in the MetricsRegistry, summaries render from that
snapshot via ``obs.summarize_*`` and print through ``obs.emit``.  A bare
print is a stat that escaped the registry — it can't be exported by
``--metrics-out``, can't be asserted by tests, and drifts from the
summary the next time someone edits one but not the other.  Ported from
``benchmarks/lint_prints.py``.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, SourceFile

RULES = (
    Rule("REPRO009", "bare-print",
         "bare print() in runtime/serving code",
         "DESIGN.md §15: a printed stat escaped the MetricsRegistry — not "
         "exportable, not assertable, drifts from the rendered summary"),
)

_SCOPE = ("src/repro/runtime/", "src/repro/launch/serve.py")
# telemetry owns no stats, but keep the door open for a debug dump
_ALLOWED = {"src/repro/runtime/telemetry.py"}


def run(sf: SourceFile) -> list:
    out: list = []
    if (not (sf.rel.startswith(_SCOPE[0]) or sf.rel == _SCOPE[1])
            or sf.rel in _ALLOWED or sf.tree is None):
        return out
    for node in ast.walk(sf.tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"):
            out.append(sf.finding(
                node, "REPRO009",
                "bare print() in runtime/serving code — record the number "
                "in the MetricsRegistry and render it via "
                "launch/obs.summarize_* / obs.emit (DESIGN.md §15)"))
    return out
