"""dtype-flow pass: online-softmax statistics must live in fp32.

REPRO001 — intraprocedural dtype/taint inference over kernel bodies in
``src/repro/kernels/``.  The online-softmax state — the running max ``m``,
denominator ``l``, accumulator ``acc``, and anything returned by
``softmax_state.init/update/merge*`` — must never be cast to, or born in,
a sub-fp32 dtype.  This is the PR 5 bug class: ``combine_splits`` once
merged bf16 split statistics in bf16 (the exp/sum followed the input
dtype), and near-tie maxima lost mass.  The fp32-on-entry upcasts now
live INSIDE ``kernels/softmax_state.py`` (DESIGN.md §13), so any sub-fp32
state sighting in a kernel body is a reintroduction.

REPRO002 — a function outside ``softmax_state.py`` containing BOTH halves
of a hand-rolled rescale chain: an ``exp``/``exp2``-of-difference (the
shifted-softmax correction weight) and a mul-add accumulate.  Either half
alone is fine (oracles call ``jax.nn.softmax``; rooflines do mul-adds);
both in one function is an online-softmax recurrence that belongs behind
the shared API.  Ported from ``benchmarks/lint_softmax.py``.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import Rule, SourceFile, functions_of, walk_scope

RULES = (
    Rule("REPRO001", "dtype-flow",
         "online-softmax state cast to or born in a sub-fp32 dtype",
         "PR 5: combine_splits merged bf16 split stats in bf16 — exp/sum "
         "followed the input dtype and near-tie maxima lost mass; stats "
         "are fp32 by contract (DESIGN.md §13)"),
    Rule("REPRO002", "rescale-chain",
         "hand-rolled online-softmax rescale chain outside softmax_state.py",
         "pre-§13 the (m, l, acc) recurrence was hand-copied across five "
         "kernel bodies and the copies drifted; one true definition lives "
         "in kernels/softmax_state.py"),
)

_KERNELS = "src/repro/kernels/"
_CHAIN_SCOPES = ("src/repro/", "benchmarks/")
_STATE_MODULE = "src/repro/kernels/softmax_state.py"

# names that ARE online-softmax state in kernel scope: m, l, acc and their
# decorated spellings (m_new, l_ref, accT, m2, ...).  "lengths"/"mask"/
# "mode" do not match: the first character after the stem must be T, _, or
# a digit.
_STATE_NAME = re.compile(r"(?:m|l|acc)(?:T|[_0-9][A-Za-z0-9_]*)?$")
# softmax_state calls whose RESULT is state (finalize returns the output)
_STATE_CALLS = {"init", "update", "merge", "merge_splits", "merge_weights"}
_SUB_FP32 = {"bfloat16", "float16", "half", "bf16", "fp16", "f16",
             "float8_e4m3fn", "float8_e5m2", "float8_e4m3",
             "float8_e4m3b11fnuz", "fp8", "int8", "uint8", "int4"}
_ARRAY_CTORS = {"zeros", "ones", "full", "empty", "array", "asarray",
                "zeros_like", "ones_like", "full_like", "empty_like"}
_EXP_NAMES = {"exp", "exp2"}


def _callee(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return ""


def _is_subfp32(node: ast.AST | None) -> bool:
    """An explicit sub-fp32 dtype expression: ``jnp.bfloat16``,
    ``"float16"``, ``jnp.dtype("int8")``, ..."""
    if node is None:
        return False
    if isinstance(node, ast.Attribute):
        return node.attr in _SUB_FP32
    if isinstance(node, ast.Name):
        return node.id in _SUB_FP32
    if isinstance(node, ast.Constant):
        return isinstance(node.value, str) and node.value in _SUB_FP32
    if isinstance(node, ast.Call) and _callee(node) == "dtype" and node.args:
        return _is_subfp32(node.args[0])
    return False


def _is_state_call(node: ast.AST) -> bool:
    """``softmax_state.update(...)`` / ``merge_splits(...)`` — a call whose
    result is online-softmax state."""
    if not isinstance(node, ast.Call):
        return False
    name = _callee(node)
    if name not in _STATE_CALLS:
        return False
    fn = node.func
    if isinstance(fn, ast.Attribute):
        # require the receiver to be the module (softmax_state.update), so
        # dict.update()/set.update() never taint
        return isinstance(fn.value, ast.Name) and "softmax" in fn.value.id
    # from-imported spellings: only the unambiguous names taint
    return name in {"merge_splits", "merge_weights"}


def _is_state_expr(node: ast.AST, tainted: set[str]) -> bool:
    """Is this expression online-softmax state?  Names (seeded + inferred),
    their subscripts/transposes, state-producing calls, tuples and binops
    of state.  ``finalize(...)`` is NOT state — its result is the attention
    output, legitimately cast back to the query dtype."""
    if isinstance(node, ast.Name):
        return node.id in tainted or bool(_STATE_NAME.match(node.id))
    if isinstance(node, ast.Subscript):
        return _is_state_expr(node.value, tainted)
    if isinstance(node, ast.Attribute) and node.attr == "T":
        return _is_state_expr(node.value, tainted)
    if isinstance(node, ast.Call):
        return _is_state_call(node)
    if isinstance(node, ast.BinOp):
        return (_is_state_expr(node.left, tainted)
                or _is_state_expr(node.right, tainted))
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_is_state_expr(e, tainted) for e in node.elts)
    return False


def _target_names(target: ast.AST):
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            yield from _target_names(e)
    elif isinstance(target, ast.Starred):
        yield from _target_names(target.value)


def _taint(fn: ast.AST) -> set[str]:
    """Forward taint propagation over the function scope: parameters and
    locals named like state seed the set; assignment from a state
    expression spreads it.  Two sweeps pick up loop-carried flows."""
    tainted: set[str] = set()
    args = getattr(fn, "args", None)
    if args is not None:
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if _STATE_NAME.match(a.arg):
                tainted.add(a.arg)
    for _ in range(2):
        for node in walk_scope(fn):
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.NamedExpr):
                value, targets = node.value, [node.target]
            else:
                continue
            if value is None:
                continue
            if any(_is_state_expr(n, tainted) for n in ast.walk(value)
                   if isinstance(n, (ast.Name, ast.Call))):
                for t in targets:
                    tainted.update(_target_names(t))
    return tainted


def _check_dtype_flow(sf: SourceFile, fn: ast.AST, out: list) -> None:
    tainted = _taint(fn)
    for node in walk_scope(fn):
        if not isinstance(node, ast.Call):
            continue
        callee = _callee(node)
        # state.astype(<sub-fp32>) — the cast
        if (callee == "astype" and node.args
                and _is_subfp32(node.args[0])
                and isinstance(node.func, ast.Attribute)
                and _is_state_expr(node.func.value, tainted)):
            out.append(sf.finding(
                node, "REPRO001",
                "online-softmax state cast to a sub-fp32 dtype — m/l/acc "
                "stay fp32; the domain belongs to kernels/softmax_state.py "
                "(DESIGN.md §13)"))
        # softmax_state.init(..., dtype=<sub-fp32>) — born narrow
        if _is_state_call(node) and callee == "init":
            for kw in node.keywords:
                if kw.arg == "dtype" and _is_subfp32(kw.value):
                    out.append(sf.finding(
                        node, "REPRO001",
                        "softmax_state.init with a sub-fp32 dtype — state "
                        "is born narrow; stats must start fp32 "
                        "(DESIGN.md §13)"))
    # state-named variable built by an array ctor carrying a sub-fp32 dtype
    for node in walk_scope(fn):
        if not isinstance(node, ast.Assign):
            continue
        value = node.value
        if not (isinstance(value, ast.Call)
                and _callee(value) in _ARRAY_CTORS):
            continue
        dtype_args = list(value.args) + [kw.value for kw in value.keywords]
        if not any(_is_subfp32(a) for a in dtype_args):
            continue
        if any(_STATE_NAME.match(name)
               for t in node.targets for name in _target_names(t)):
            out.append(sf.finding(
                node, "REPRO001",
                "online-softmax state born in a sub-fp32 dtype — allocate "
                "m/l/acc as fp32 (DESIGN.md §13)"))


# --- REPRO002: the ported lint_softmax chain detector -----------------------

def _is_exp_of_sub(node: ast.AST) -> bool:
    """``exp(... - ...)`` / ``exp2(... - ...)`` — a shifted exponential."""
    if not isinstance(node, ast.Call) or not node.args:
        return False
    if _callee(node) not in _EXP_NAMES:
        return False
    return any(isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Sub)
               for sub in ast.walk(node.args[0]))


def _is_mul_add_store(node: ast.AST) -> bool:
    """``y = a * b + c`` or ``y += a * b`` — a rescaled accumulate."""
    if isinstance(node, (ast.Assign, ast.AnnAssign)):
        v = node.value
        return (isinstance(v, ast.BinOp) and isinstance(v.op, ast.Add)
                and any(isinstance(s, ast.BinOp)
                        and isinstance(s.op, ast.Mult)
                        for s in (v.left, v.right)))
    if isinstance(node, ast.AugAssign):
        return (isinstance(node.op, ast.Add)
                and isinstance(node.value, ast.BinOp)
                and isinstance(node.value.op, ast.Mult))
    return False


def _check_chain(sf: SourceFile, fn: ast.AST, out: list) -> None:
    body = list(walk_scope(fn))
    if (any(_is_exp_of_sub(n) for n in body)
            and any(_is_mul_add_store(n) for n in body)):
        out.append(sf.finding(
            fn, "REPRO002",
            f"function `{fn.name}` hand-rolls an online-softmax rescale "
            f"chain (exp-of-difference + mul-add accumulate); use "
            f"repro.kernels.softmax_state instead (DESIGN.md §13)"))


def run(sf: SourceFile) -> list:
    out: list = []
    in_kernels = sf.rel.startswith(_KERNELS)
    in_chain_scope = (sf.rel.startswith(_CHAIN_SCOPES)
                      and sf.rel != _STATE_MODULE)
    if not (in_kernels or in_chain_scope) or sf.tree is None:
        return out
    for fn in functions_of(sf.tree):
        if in_kernels and sf.rel != _STATE_MODULE:
            _check_dtype_flow(sf, fn, out)
        if in_chain_scope:
            _check_chain(sf, fn, out)
    return out
