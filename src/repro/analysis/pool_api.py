"""pool-invariant pass: BlockPool/PrefixCache private state stays home.

REPRO007 — any read or write of the paged-cache/prefix-cache private
state (`_free`, `_chain`, `_nshared`, `_budget`, `_host_free`, `_lru`,
`_pinned`, `_root`, `_uid`, `_assert_writable`) outside
``runtime/paged_cache.py`` / ``runtime/prefix_cache.py``.  The free-list /
refcount / trie invariants from PRs 4–6 (free ⟺ refcount 0 conservation,
COW write guards, LRU-leaf-only eviction) hold because every mutation
funnels through the public API — ``admit/extend/append/truncate/swap_*/
release`` for state motion, ``audit/check_conservation/observe/stats/
free_ids/cached_block_ids`` for inspection.  A test or benchmark peeking
at ``bp._free`` works until the representation changes; hypothesis stress
tests then catch the corruption only after the fact.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, SourceFile

RULES = (
    Rule("REPRO007", "pool-private-state",
         "BlockPool/PrefixCache private state touched outside its module",
         "PRs 4–6: free-list/refcount/trie corruption was only caught by "
         "hypothesis stress tests after the fact; the invariants hold "
         "because mutation funnels through the public API"),
)

_OWNERS = ("src/repro/runtime/paged_cache.py",
           "src/repro/runtime/prefix_cache.py")
# attribute names distinctive to BlockPool/PrefixCache internals
_PRIVATE = {"_free", "_chain", "_nshared", "_budget", "_host_free",
            "_lru", "_pinned", "_root", "_uid", "_assert_writable"}


def run(sf: SourceFile) -> list:
    out: list = []
    if sf.rel in _OWNERS or sf.tree is None:
        return out
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Attribute) and node.attr in _PRIVATE:
            kind = ("written" if isinstance(node.ctx, (ast.Store, ast.Del))
                    else "read")
            out.append(sf.finding(
                node, "REPRO007",
                f"private BlockPool/PrefixCache state `.{node.attr}` "
                f"{kind} outside runtime/paged_cache.py / "
                f"runtime/prefix_cache.py — go through the public "
                f"audit/observe/accessor API (DESIGN.md §16)"))
    return out
