"""``python -m repro.analysis`` — the unified invariant analyzer runner.

Exit codes are stable and CI-facing:

    0  clean (no findings, or all suppressed/baselined)
    1  findings
    2  usage or internal error (bad flag, unreadable root, git failure)

Modes:

    python -m repro.analysis                     # full tree
    python -m repro.analysis --diff              # only files changed vs git
    python -m repro.analysis src/repro/foo.py    # explicit file set
    python -m repro.analysis --select REPRO002   # one rule (the shims)
    python -m repro.analysis --list-rules        # the rule catalog
    python -m repro.analysis --write-baseline    # grandfather current tree
"""
from __future__ import annotations

import argparse
import pathlib
import subprocess
import sys

from repro.analysis import (baseline, core, donation, dtype_flow, pool_api,
                            prints, retrace)

PASSES = (dtype_flow, retrace, pool_api, donation, prints)

_PARSE_RULE = core.Rule(
    "REPRO000", "parse-error", "file failed to parse",
    "an unparseable file is invisible to every other rule")

ALL_RULES = (_PARSE_RULE,) + tuple(r for p in PASSES for r in p.RULES)


def run_passes(sf: core.SourceFile, select: set[str] | None = None):
    """All (kept, suppressed) findings for one file."""
    found: list[core.Finding] = []
    if sf.tree is None and sf.parse_error is not None:
        e = sf.parse_error
        found.append(core.Finding(sf.rel, e.lineno or 1, "REPRO000",
                                  f"syntax error: {e.msg}"))
    else:
        for p in PASSES:
            found.extend(p.run(sf))
    if select is not None:
        found = [f for f in found if f.rule in select]
    kept = [f for f in found if not sf.suppressed(f)]
    return kept, len(found) - len(kept)


def _git_changed(root: pathlib.Path) -> set[str]:
    """Repo-relative posix paths changed vs HEAD, plus untracked files."""
    def lines(*cmd):
        return subprocess.run(
            ["git", "-C", str(root), *cmd], check=True,
            capture_output=True, text=True).stdout.splitlines()
    changed = lines("diff", "--name-only", "HEAD")
    changed += lines("ls-files", "--others", "--exclude-standard")
    return {p.strip() for p in changed if p.strip().endswith(".py")}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Unified invariant analyzer: dtype-flow, retrace-"
                    "hazard, pool-API, donation-safety, bare-print "
                    "(DESIGN.md §16).")
    ap.add_argument("paths", nargs="*",
                    help="restrict the scan to these repo-relative files")
    ap.add_argument("--diff", action="store_true",
                    help="scan only files changed vs git HEAD (+ untracked)")
    ap.add_argument("--root", default=None,
                    help="tree to scan (default: this checkout)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: <root>/"
                         f"{baseline.DEFAULT_NAME})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather every current finding into the "
                         "baseline file and exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (e.g. REPRO002)")
    ap.add_argument("--list-rules", action="store_true")
    try:
        args = ap.parse_args(argv)
    except SystemExit as e:          # argparse exits 0 on --help, 2 on usage
        return 0 if e.code in (0, None) else 2

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  [{r.name}] {r.summary}")
            print(f"         why: {r.rationale}")
        return 0

    root = pathlib.Path(args.root).resolve() if args.root else core.REPO
    if not root.is_dir():
        print(f"repro.analysis: root {root} is not a directory",
              file=sys.stderr)
        return 2

    select = None
    if args.select:
        select = {s.strip().upper() for s in args.select.split(",")}
        known = {r.id for r in ALL_RULES}
        if not select <= known:
            print(f"repro.analysis: unknown rule(s) "
                  f"{sorted(select - known)}; see --list-rules",
                  file=sys.stderr)
            return 2

    only: set[str] | None = None
    if args.diff:
        try:
            only = _git_changed(root)
        except (subprocess.CalledProcessError, FileNotFoundError) as e:
            print(f"repro.analysis: --diff needs a git checkout at {root} "
                  f"({e})", file=sys.stderr)
            return 2
    if args.paths:
        explicit = {pathlib.Path(p).as_posix() for p in args.paths}
        only = explicit if only is None else (only & explicit)

    findings: list[core.Finding] = []
    n_suppressed = n_files = 0
    for sf in core.iter_source_files(root, only):
        n_files += 1
        kept, sup = run_passes(sf, select)
        findings.extend(kept)
        n_suppressed += sup

    bl_path = (pathlib.Path(args.baseline) if args.baseline
               else root / baseline.DEFAULT_NAME)
    if args.write_baseline:
        baseline.write(bl_path, findings)
        print(f"repro.analysis: wrote {len(findings)} baseline entr"
              f"{'y' if len(findings) == 1 else 'ies'} to {bl_path}")
        return 0
    kept, baselined, stale = baseline.split(findings, baseline.load(bl_path))

    for f in sorted(kept, key=lambda f: (f.rel, f.line, f.rule)):
        print(f.render())
    for fp in stale:
        print(f"repro.analysis: stale baseline entry {fp} (fixed? remove "
              f"it from {bl_path.name})")
    tallies = []
    if n_suppressed:
        tallies.append(f"{n_suppressed} suppressed")
    if baselined:
        tallies.append(f"{len(baselined)} baselined")
    extra = f" ({', '.join(tallies)})" if tallies else ""
    if kept:
        print(f"repro.analysis: {len(kept)} finding(s) across {n_files} "
              f"file(s){extra} — scan just your changes with "
              f"`python -m repro.analysis --diff`")
        return 1
    print(f"repro.analysis: ok — {len(PASSES)} passes, "
          f"{len(ALL_RULES) - 1} rules, {n_files} files clean{extra}")
    return 0
