"""donation-safety pass: a donated buffer is gone after the call.

REPRO008 — an argument donated through ``jax.jit(..., donate_argnums=)``
that is read again later in the same scope without being rebound first.
Donation hands the buffer to XLA: the old array aliases freed (or
reused) memory, and reading it is undefined — sometimes stale bytes,
sometimes a runtime error, never a type error.  The serve loop's donated
decode/verify launches are safe only because every call site immediately
rebinds the cache (``logits, holder["cache"] = step_fn(params,
holder["cache"], ...)``) — a convention this pass machine-enforces.

The check is intraprocedural and path-based: the donated argument
expression is reduced to an access path (``cache``, ``holder['cache']``,
``self.cache``); any LOAD of that path on a later line, before a STORE
rebinds it, is flagged.  A store in the calling statement itself (the
rebind idiom) clears the path immediately.  Nested function bodies are
skipped — they execute at another time.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, SourceFile, functions_of, walk_scope

RULES = (
    Rule("REPRO008", "use-after-donate",
         "argument donated via donate_argnums referenced after the call",
         "the serve loop's donated verify launch was guarded only by the "
         "rebind convention; a read of the donated buffer aliases freed "
         "memory — stale bytes or a runtime error, never a type error"),
)


def _donate_nums(call: ast.Call) -> tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            return tuple(e.value for e in v.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
    return ()


def _is_jit(call: ast.AST) -> bool:
    if not isinstance(call, ast.Call):
        return False
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else "")
    return name == "jit"


def _path(node: ast.AST):
    """Reduce an expression to a hashable access path, or None.

    ``cache`` -> ('cache',); ``holder["cache"]`` -> ('holder', "'cache'");
    ``self.cache`` -> ('self', '.cache').  Non-constant subscripts are not
    tracked (the alias set is unknowable statically)."""
    if isinstance(node, ast.Name):
        return (node.id,)
    if isinstance(node, ast.Attribute):
        base = _path(node.value)
        return base + ("." + node.attr,) if base else None
    if isinstance(node, ast.Subscript):
        base = _path(node.value)
        if base is None:
            return None
        sl = node.slice
        if isinstance(sl, ast.Constant):
            return base + (repr(sl.value),)
        return None
    return None


def _statements(scope: ast.AST):
    """Every statement in the scope in source order, nested compound
    bodies flattened, nested function/class bodies excluded."""
    stmts = []
    for node in walk_scope(scope):
        if isinstance(node, ast.stmt) and not isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            stmts.append(node)
    return sorted(stmts, key=lambda s: (s.lineno, s.col_offset))


def _loads_stores(stmt: ast.stmt):
    """(loaded paths, stored paths) of one statement, skipping nested
    function bodies."""
    loads, stores = [], []
    for node in walk_scope(stmt):
        p = _path(node) if isinstance(
            node, (ast.Name, ast.Attribute, ast.Subscript)) else None
        if p is None:
            continue
        ctx = getattr(node, "ctx", None)
        if isinstance(ctx, (ast.Store, ast.Del)):
            stores.append(p)
        elif isinstance(ctx, ast.Load):
            loads.append(p)
    # only the OUTERMOST path nodes matter, but inner Name loads of a
    # subscripted store (holder["cache"] = ...) appear as loads of
    # ('holder',); that read is part of the store and harmless.
    return loads, stores


def _check_scope(sf: SourceFile, scope: ast.AST, out: list) -> None:
    # donated-jit aliases bound in this scope
    donated_of: dict[str, tuple[int, ...]] = {}
    for node in walk_scope(scope):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and _is_jit(node.value)):
            nums = _donate_nums(node.value)
            if nums:
                donated_of[node.targets[0].id] = nums
    if not donated_of:
        return

    stmts = _statements(scope)
    # pending[path] = (call lineno, alias name) awaiting a rebind
    pending: dict[tuple, tuple[int, str]] = {}
    for stmt in stmts:
        loads, stores = _loads_stores(stmt)
        # flag loads of still-donated paths (reads inside the statement
        # that rebinds the path at the SAME line are the rebind idiom)
        stored_here = set(stores)
        for p in loads:
            if p in pending and p not in stored_here:
                lineno, alias = pending[p]
                out.append(sf.finding(
                    stmt, "REPRO008",
                    f"`{'.'.join(map(str, p))}` was donated to jitted "
                    f"`{alias}` (line {lineno}) and read again without "
                    f"rebinding — the donated buffer aliases freed memory"))
                del pending[p]
        for p in stores:
            pending.pop(p, None)
        # new donations from calls in this statement
        for node in ast.walk(stmt):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in donated_of):
                continue
            for i in donated_of[node.func.id]:
                if i < len(node.args):
                    p = _path(node.args[i])
                    if p is not None and p not in stored_here:
                        pending[p] = (node.lineno, node.func.id)
    # unrebound paths at scope end are fine: nothing read them again


def run(sf: SourceFile) -> list:
    out: list = []
    if sf.tree is None:
        return out
    _check_scope(sf, sf.tree, out)
    for fn in functions_of(sf.tree):
        _check_scope(sf, fn, out)
    return out
