"""Deterministic synthetic data pipeline with host-sharded loading.

Each step's global batch is a pure function of (seed, step) so any worker —
or a restarted worker — regenerates exactly its shard: checkpoint/restart
and elastic re-meshing need no data-loader state beyond the step counter.
A background prefetch thread keeps `depth` batches in flight.
"""
from __future__ import annotations

import queue
import threading
from collections.abc import Iterator
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.frontend import FRONTEND_DIMS


@dataclass(frozen=True)
class DataConfig:
    seed: int = 17
    global_batch: int = 8
    seq_len: int = 128


def batch_struct(cfg, data: DataConfig):
    """abstract ShapeDtypeStructs for one batch (matches launch.input_specs)."""
    B, S = data.global_batch, data.seq_len
    if cfg.frontend:
        return {"embeds": jax.ShapeDtypeStruct((B, S, FRONTEND_DIMS[cfg.frontend]),
                                               cfg.jax_dtype),
                "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def make_batch(cfg, data: DataConfig, step: int, *, lo: int = 0,
               hi: int | None = None) -> dict:
    """Deterministic batch for `step`; [lo, hi) selects a host's batch rows."""
    hi = data.global_batch if hi is None else hi
    rng = np.random.default_rng((data.seed, step))
    tokens = rng.integers(0, cfg.vocab_size, size=(data.global_batch, data.seq_len),
                          dtype=np.int32)[lo:hi]
    if cfg.frontend:
        emb = rng.standard_normal(
            (data.global_batch, data.seq_len, FRONTEND_DIMS[cfg.frontend]),
            dtype=np.float32)[lo:hi]
        out = {"embeds": emb.astype(cfg.jax_dtype), "targets": tokens}
    else:
        out = {"tokens": tokens}
    return out


def device_batch(cfg, data: DataConfig, step: int, sharding) -> dict:
    """Globally-sharded jax arrays built shard-by-shard (multi-host pattern:
    each host materializes only its rows via make_array_from_callback)."""
    host = make_batch(cfg, data, step)

    def put(arr):
        arr = np.asarray(arr)
        return jax.make_array_from_callback(
            arr.shape, sharding(arr.ndim), lambda idx: arr[idx])
    return {k: put(v) for k, v in host.items()}


class Prefetcher:
    """Background-thread batch prefetch (depth-bounded)."""

    def __init__(self, cfg, data: DataConfig, sharding, start_step: int = 0,
                 depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = device_batch(cfg, data, step, sharding)
                self._q.put((step, batch))
                step += 1
        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self) -> Iterator:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
