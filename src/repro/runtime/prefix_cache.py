"""Radix-tree prefix cache: refcounted KV-block sharing across requests.

Serving traffic shares long prompt prefixes — system prompts, few-shot
templates, multi-turn history — and the cheapest prefill is the one that is
skipped.  This module maps shared prefixes to chains of physical KV blocks
in the :class:`~repro.runtime.paged_cache.BlockPool` through a
BLOCK-GRANULAR radix tree over prompt token ids: each node covers exactly
one ``block_size``-token block, its edge is labeled by that block's token
tuple, and walking a new prompt block-by-block yields the longest cached
block-aligned prefix.  Admission (launch/serve.py) maps the matched chain
into the new request's block table with a refcount bump per block
(:meth:`BlockPool.admit_shared`) and starts chunked prefill at the match
offset — zero prefill tokens are spent on the shared prefix, and MLA's
compressed latent cache (a single 576-wide stream per token) makes the
retained blocks nearly free in memory.

Lifecycle (DESIGN.md §10):
  · ``insert`` is called when a request finishes PREFILL (not release): the
    prompt's full blocks enter the trie, each taking one pool reference, so
    concurrent and queued requests can share them while the donor is still
    decoding.  Insert under an existing token path DEDUPES: the first
    cached physical block wins, the duplicate stays owned by its slot and
    is freed on release.
  · ``release`` (BlockPool) drops the slot's references; trie-cached prompt
    blocks survive at refcount >= 1 as an LRU-evictable cached set, decode
    tail blocks fall to zero and return to the free list.
  · Under pressure the free list reclaims from LRU LEAVES (``evict_lru``):
    only leaves are evictable (never dangles a cached child chain), and
    only trie-exclusive blocks (pool refcount == 1) are taken — evicting a
    block a live slot still maps would free nothing and is skipped, so
    eviction can never free a live block by construction.

The trie stores host-side ids only; KV bytes always live in the pool.
"""
from __future__ import annotations

import itertools
from collections import OrderedDict

import numpy as np


class _Node:
    """One cached KV block: edge label `key` (the block's token tuple) from
    `parent`, the physical pool block `block_id`, children keyed by their
    own token tuples."""
    __slots__ = ("key", "block_id", "parent", "children", "uid")

    def __init__(self, key, block_id, parent, uid):
        self.key = key
        self.block_id = block_id
        self.parent = parent
        self.children = {}
        self.uid = uid


class PrefixCache:
    """Block-granular radix tree over prompt token ids -> physical block
    chains, with LRU leaf eviction.  One instance per BlockPool; the pool
    owns the refcounts, the trie owns the recency order."""

    def __init__(self, block_size: int):
        assert block_size >= 1
        self.block_size = block_size
        self._root = _Node(None, None, None, -1)
        self._lru: OrderedDict[int, _Node] = OrderedDict()  # LRU -> MRU
        self._uid = itertools.count()
        # persistent pins (block id -> pin count): chains a PREEMPTED
        # request will re-match at restore (DESIGN.md §12).  Pinning is
        # BEST-EFFORT pressure steering, not protection: pinned blocks are
        # evicted last (second pass of evict_lru), never exempted — a
        # recompute-restore whose pinned prefix was reclaimed anyway just
        # re-prefills it, so reclaimable() stays an exact supply.
        self._pinned: dict[int, int] = {}
        self.lookups = 0
        self.hits = 0
        self.matched_tokens = 0
        self.inserted_blocks = 0
        self.evictions = 0
        self.pinned_evictions = 0

    def __len__(self) -> int:
        """Cached blocks (= trie nodes)."""
        return len(self._lru)

    def _keys(self, tokens) -> list[tuple]:
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        bs = self.block_size
        return [tuple(toks[i * bs:(i + 1) * bs])
                for i in range(len(toks) // bs)]

    def match(self, tokens, record: bool = True):
        """Longest cached block-aligned prefix of `tokens`.

        Returns ``(chain, matched_len)``: the physical block ids holding
        the first ``matched_len`` tokens (all visited nodes are touched to
        MRU).  Capped so at least ONE prompt token is always left to
        prefill — the last position's logits must be computed fresh to seed
        the first decode token, so a fully-cached block-aligned prompt
        recomputes its final block.

        ``record=False`` leaves the hit/lookup counters alone: a scheduler
        that re-matches a still-queued request every step (the match can
        GROW while it waits — donors finish prefill, tries fill) would
        otherwise count one request N times and inflate the hit rate; it
        calls :meth:`record` once, on successful admission."""
        if record:
            self.lookups += 1
        n_tok = int(np.asarray(tokens).size)
        node, chain = self._root, []
        for key in self._keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            self._lru.move_to_end(child.uid)
            chain.append(child.block_id)
            node = child
        while chain and len(chain) * self.block_size >= n_tok:
            chain.pop()
        matched = len(chain) * self.block_size
        if record and chain:
            self.hits += 1
            self.matched_tokens += matched
        return chain, matched

    def record(self, matched: int) -> None:
        """Count one lookup (and its hit, if any) — the deferred-stats
        companion of ``match(record=False)``, called once per ADMITTED
        request so refusal retries don't inflate the hit rate."""
        self.lookups += 1
        if matched:
            self.hits += 1
            self.matched_tokens += matched

    def insert(self, tokens, chain, pool) -> int:
        """Cache the full-block prefix of `tokens`, whose physical blocks
        are `chain` (the slot's logical block chain, shared + fresh — only
        the first ``len(tokens) // block_size`` entries are used; a partial
        tail block is never cached).  Every NEWLY inserted block takes one
        pool reference (:meth:`BlockPool.ref_block`); a block already
        cached under the same token path is deduped — the existing physical
        block is kept and the caller's duplicate stays owned by its slot
        alone.  Returns the number of blocks newly inserted."""
        node, new = self._root, 0
        for key, bid in zip(self._keys(tokens), chain, strict=False):
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(bid), node, next(self._uid))
                node.children[key] = child
                pool.ref_block(int(bid))
                new += 1
            self._lru[child.uid] = child
            self._lru.move_to_end(child.uid)
            node = child
        self.inserted_blocks += new
        return new

    def pin_chain(self, chain) -> None:
        """Take a best-effort pin on every block of `chain`: pinned blocks
        are passed over by :meth:`evict_lru`'s first pass, so a preempted
        request's cached prompt survives routine pressure and its restore
        stays a refcount bump instead of a re-prefill.  Pins nest (a block
        two preempted requests depend on needs two unpins) and do NOT
        protect absolutely — under exhaustive pressure the second pass
        reclaims pinned blocks too."""
        for bid in chain:
            bid = int(bid)
            self._pinned[bid] = self._pinned.get(bid, 0) + 1

    def unpin_chain(self, chain) -> None:
        """Drop one pin per block of `chain` (restore-complete, or the
        request was cancelled).  Unpinning a block evicted meanwhile is a
        no-op — the pin was best-effort and the eviction already counted."""
        for bid in chain:
            bid = int(bid)
            n = self._pinned.get(bid, 0)
            if n <= 1:
                self._pinned.pop(bid, None)
            else:
                self._pinned[bid] = n - 1

    def evict_lru(self, pool, protect=frozenset()):
        """Evict the least-recently-used evictable LEAF and drop its pool
        reference; returns the freed physical block id, or None when
        nothing is evictable.  A node is evictable iff it has no children
        (so no cached chain dangles), the trie holds the block's ONLY
        reference (pool refcount == 1 — evicting a slot-shared block frees
        no memory and could strand a mapper's future re-match), and its
        block is not in `protect` (a chain the caller matched but has not
        yet mapped).  Evicting a leaf exposes its parent for the next
        round, so repeated calls peel cached chains back to front.
        Two passes: unpinned leaves first; PINNED blocks (chains preempted
        requests will re-match, :meth:`pin_chain`) go only when nothing
        else is left, so pins steer pressure without shrinking the
        reclaimable supply."""
        for take_pinned in (False, True):
            for uid, node in self._lru.items():
                if node.children or node.block_id in protect:
                    continue
                if (node.block_id in self._pinned) != take_pinned:
                    continue
                if int(pool.ref[node.block_id]) != 1:
                    continue
                del node.parent.children[node.key]
                del self._lru[uid]
                freed = pool.unref_block(node.block_id)
                assert freed, "trie held the only reference, block must free"
                self.evictions += 1
                if take_pinned:
                    self.pinned_evictions += 1
                return node.block_id
        return None

    def reclaimable(self, pool, protect=frozenset()) -> int:
        """Blocks repeated :meth:`evict_lru` calls could actually free:
        cached blocks whose ONLY reference is the trie and that are not
        protected.  Slot references are taken on root-anchored prefixes,
        so trie-exclusive nodes are downward-closed — every one of them is
        reachable by peeling leaves, making this an exact supply, not a
        bound.  The scheduler checks it BEFORE evicting: an admission that
        eviction cannot satisfy must refuse without trading away cache
        state other requests would have hit."""
        return sum(1 for n in self._lru.values()
                   if int(pool.ref[n.block_id]) == 1
                   and n.block_id not in protect)

    def cached_block_ids(self) -> set[int]:
        """Snapshot of every physical block id the trie holds a reference
        on — the public inspection surface for conservation tests; recency
        order is untouched (unlike :meth:`match`)."""
        return {n.block_id for n in self._lru.values()}

    def peek_chain(self, tokens) -> list[int]:
        """Physical block ids cached for the full-block prefix of
        ``tokens`` — a side-effect-free :meth:`match`: no LRU touch, no
        counter motion, and no last-token cap (the full cached chain)."""
        node, chain = self._root, []
        for key in self._keys(tokens):
            child = node.children.get(key)
            if child is None:
                break
            chain.append(child.block_id)
            node = child
        return chain

    def stats(self) -> dict:
        """Counters for serve-loop observability (DESIGN.md §10)."""
        return {"lookups": self.lookups, "hits": self.hits,
                "hit_rate": self.hits / max(1, self.lookups),
                "matched_tokens": self.matched_tokens,
                "inserted_blocks": self.inserted_blocks,
                "evictions": self.evictions,
                "pinned_evictions": self.pinned_evictions,
                "pinned_blocks": len(self._pinned),
                "cached_blocks": len(self._lru)}
