"""SLO-aware multi-tenant scheduler: admission, preemption, restore.

The serve loop (launch/serve.py) was FCFS admit-or-refuse: under burst
load it parked refused requests and could never reclaim capacity from
running sequences (ROADMAP item 4).  This module turns refusal-under-
pressure into degrade-under-pressure on top of the paged substrate:

  · Requests carry a PRIORITY CLASS (0 = most important) and move through
    WAITING -> RUNNING -> DONE, with PREEMPTED as the pressure detour.
  · Admission is head-of-line strict over a candidate order of
    (priority, PREEMPTED-before-WAITING, arrival, id): one refusal stops
    the admission round — a lower-priority request must never slip past a
    refused higher-priority one just because it is smaller.
  · A refused candidate backs off exponentially (``next_try`` ticks) and
    retries — never a permanent refusal.  When everything is backing off
    and nothing runs, an IDLE KICK clears the backoffs (progress
    guarantee: an empty machine never sits idle on a non-empty queue).
  · When a candidate cannot be placed, the scheduler PREEMPTS strictly-
    lower-priority victims (victim order: lowest priority class first,
    then shortest progress — cheapest to redo — then highest slot).  The
    strictness is the livelock guard: equals never preempt each other, so
    a preempted request re-admitted later cannot bounce its own usurper.

Two evacuation modes (DESIGN.md §12), both restoring BITWISE-identical
greedy outputs:

  swap       The victim's written device blocks are copied to host RAM
             (``BlockPool.swap_out`` host-tier accounting; a KVOps
             adapter moves the bytes), restore copies them back into a
             fresh admission (``swap_in``).  Bitwise trivially: the same
             bits come back.
  recompute  The victim's blocks are dropped (``release``); restore
             re-prefills the prompt — the prefix-cache trie usually still
             holds the prompt blocks (they are PINNED while the victim is
             out, steering LRU eviction away) — and then TEACHER-FORCES
             the already-delivered tokens back through the decode kernel
             (``Request.replay``).  Prefill and decode kernels are not
             bitwise-interchangeable, so generated tokens must replay
             through the same decode path that first produced them; the
             prompt re-prefill is bitwise by the global-chunk-grid
             invariant (§10).  Replayed tokens are not delivered twice.

SLO controls are WALL-CLOCK driven but bitwise-safe — they only reorder
work and resize the per-step prefill share, never a request's token
sequence:

  · ``slo_ttft_ms``: a request past its time-to-first-token budget gets
    effective priority -1 (ahead of every class, still preemption-inert).
  · ``slo_itl_ms``: when the recent delivered inter-token latency runs
    over budget, :meth:`Scheduler.prefill_quota` shrinks the prefill
    share of the step token budget (chunked-prefill interference is the
    knob) — chunk SHAPES never change, only how many run per step.

The scheduler is DEVICE-FREE: numpy + BlockPool + PrefixCache.  Device
bytes move through the three :class:`KVOps` closures serve.py provides
(read_blocks / write_blocks / copy_block over the donated cache pytree).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from collections.abc import Callable

import numpy as np

from repro.runtime import telemetry

WAITING = "WAITING"
RUNNING = "RUNNING"
PREEMPTED = "PREEMPTED"
DONE = "DONE"


@dataclasses.dataclass
class KVOps:
    """Device-side byte movers the scheduler stays agnostic of.

    read_blocks(ids) -> opaque host rows for physical blocks `ids`;
    write_blocks(ids, rows, start) writes host rows for LOGICAL blocks
    [start, start + len(ids)) back into physical blocks `ids`;
    copy_block(src, dst) is the eager-COW duplicate.  serve.py binds them
    to models.model.{read,write}_paged_blocks / copy_paged_block over the
    live cache; pool-only tests bind plain dict stores."""
    read_blocks: Callable
    write_blocks: Callable
    copy_block: Callable


def null_kv_ops() -> KVOps:
    """KVOps for pool-accounting tests with no device state."""
    return KVOps(read_blocks=lambda ids: None,
                 write_blocks=lambda ids, rows, start: None,
                 copy_block=lambda src, dst: None)


@dataclasses.dataclass
class SchedulerConfig:
    preemption: str = "recompute"        # "swap" | "recompute"
    slo_ttft_ms: float = 0.0             # 0 = off
    slo_itl_ms: float = 0.0              # 0 = off
    backoff_base: int = 1                # ticks; doubles per failed attempt
    backoff_cap: int = 1                 # cap=1 == retry-every-tick (the
    #                                      pre-scheduler serve behavior;
    #                                      --retry-backoff raises it)

    def __post_init__(self):
        assert self.preemption in ("swap", "recompute")
        assert self.backoff_base >= 1 and self.backoff_cap >= 1


@dataclasses.dataclass
class Request:
    """One serving request through the WAITING/RUNNING/PREEMPTED/DONE
    lifecycle.  ``out`` is the delivered-token transcript — under greedy
    decoding it is the bitwise ground truth a restore must extend, and
    the teacher-forcing source for recompute replay."""
    id: int
    prompt: np.ndarray
    gen: int
    priority: int = 0
    arrival: int = 0                     # tick the request becomes visible
    state: str = WAITING
    slot: int | None = None
    pf_pos: int = 0                      # prompt tokens resident in KV
    decoding: bool = False               # prompt fully prefilled
    cur: int = -1                        # next token to feed the decoder
    remaining: int = 0                   # delivery budget left
    out: list = dataclasses.field(default_factory=list)
    replay: deque = dataclasses.field(default_factory=deque)
    matched: int = 0                     # trie match at last placement
    attempts: int = 0                    # refused placements since placed
    next_try: int = 0                    # earliest retry tick
    preemptions: int = 0
    pinned: list | None = None        # trie chain pinned while out
    admit_seq: int = -1                  # FCFS order among cold slots
    t_arrival: float = 0.0               # wall clock, for SLO accounting
    t_first: float | None = None
    t_last: float | None = None
    ttft_ms: float | None = None

    @property
    def plen(self) -> int:
        return int(np.asarray(self.prompt).size)

    @property
    def total(self) -> int:
        return self.plen + int(self.gen)


_COUNTER_NAMES = ("admissions", "refusals", "idle_kicks", "preempts_swap",
                  "preempts_recompute", "restores_swap",
                  "restores_recompute", "failures", "slo_boosts")


class Scheduler:
    """Priority/SLO admission + preemption policy over one BlockPool.

    Owns the request queue and the slot->request map; the serve loop owns
    the device work (prefill chunks, decode steps) and calls back in:
    ``add`` on arrival, ``admit`` once per tick, ``deliver`` per generated
    token, ``finish`` at budget exhaustion, ``fail_running`` on injected
    worker failures, ``cancel`` to drop a request in any state."""

    def __init__(self, pool, prefix, kv: KVOps | None = None,
                 cfg: SchedulerConfig | None = None, *,
                 metrics: telemetry.MetricsRegistry | None = None,
                 tracer=None):
        self.pool = pool
        self.prefix = prefix
        self.kv = kv if kv is not None else null_kv_ops()
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        if self.cfg.preemption == "swap":
            assert pool.host_blocks > 0, \
                "swap preemption needs a host tier (--host-blocks)"
        self.queue: list[Request] = []       # WAITING + PREEMPTED
        self.by_slot: dict[int, Request] = {}
        self.done: dict[int, Request] = {}
        self._host_rows: dict[int, object] = {}   # req id -> swapped bytes
        self._itl_recent: deque = deque(maxlen=64)
        self.refused_ids: set = set()
        self.n_admitted = 0
        self.prefill_tokens_saved = 0
        # all stats live in a MetricsRegistry (a private one when the
        # caller didn't wire telemetry in — the counters/class_stats APIs
        # then behave exactly as before).  `tracer`, when given, gets one
        # lifecycle instant per request state transition.
        self.metrics = metrics if metrics is not None \
            else telemetry.MetricsRegistry()
        self.tracer = tracer
        self._c = {n: self.metrics.counter(f"sched/{n}")
                   for n in _COUNTER_NAMES}
        self._classes: set[int] = set()

    @property
    def counters(self) -> dict:
        """Counter name -> value (the pre-registry dict shape; tests and
        the serve summary read this)."""
        return {n: c.value for n, c in self._c.items()}

    # -------------------------------------------------------- telemetry
    def _instant(self, name: str, r: Request, args: dict | None = None
                 ) -> None:
        """One lifecycle instant on the request's trace thread
        (tid 1000+id; tid 0 is the engine timeline)."""
        if self.tracer is not None:
            self.tracer.instant(name, tid=1000 + r.id, args=args)

    def _class_hist(self, r: Request, kind: str) -> telemetry.Histogram:
        self._classes.add(r.priority)
        return self.metrics.histogram(
            f"sched/class{r.priority}/{kind}_ms")

    # ------------------------------------------------------------ lifecycle
    def add(self, req: Request, now: float = 0.0) -> None:
        assert req.state == WAITING
        req.t_arrival = now
        req.remaining = int(req.gen)
        self.queue.append(req)
        self._instant("enqueued", req,
                      {"priority": req.priority, "plen": req.plen,
                       "gen": int(req.gen)})

    def running(self) -> list:
        """RUNNING requests in slot order (the per-step iteration order)."""
        return [self.by_slot[s] for s in sorted(self.by_slot)]

    def deliver(self, r: Request, token: int, now: float) -> None:
        """Account one DELIVERED token (never called for replayed ones):
        transcript, budget, and the wall-clock TTFT/ITL samples the class
        stats and the ITL controller read."""
        r.out.append(int(token))
        r.remaining -= 1
        if r.t_first is None:
            r.t_first = now
            r.ttft_ms = (now - r.t_arrival) * 1e3
            self._class_hist(r, "ttft").record(r.ttft_ms)
        else:
            itl = (now - r.t_last) * 1e3
            self._class_hist(r, "itl").record(itl)
            self._itl_recent.append(itl)
        r.t_last = now

    def finish(self, r: Request) -> None:
        assert r.state == RUNNING and r.remaining == 0 and not r.replay
        self.pool.release(r.slot)
        del self.by_slot[r.slot]
        r.slot, r.decoding, r.state = None, False, DONE
        self.done[r.id] = r
        self._classes.add(r.priority)
        self.metrics.inc(f"sched/class{r.priority}/done")
        self._instant("finished", r, {"tokens": len(r.out)})

    def cancel(self, r: Request) -> None:
        """Drop a request in any live state.  The PREEMPTED-with-swap case
        is the double-unref edge (ISSUE 6 satellite): the victim's device
        references were already dropped at swap_out — its trie-cached
        prompt blocks belong to the trie alone — so cancelling frees HOST
        ids only (``swap_free``) and must not touch device refcounts."""
        if r.state == RUNNING:
            self.pool.release(r.slot)
            del self.by_slot[r.slot]
        elif r.state == PREEMPTED:
            if r.id in self.pool.swapped:
                self.pool.swap_free(r.id)
                self._host_rows.pop(r.id, None)
            self._unpin(r)
            self.queue.remove(r)
        elif r.state == WAITING:
            self.queue.remove(r)
        r.slot, r.decoding, r.state = None, False, DONE

    # ------------------------------------------------------------ admission
    def admit(self, tick: int, now: float = 0.0) -> None:
        """One admission round: place candidates in strict head-of-line
        order, preempting strictly-lower-priority victims when placement
        refuses; stop at the first candidate that cannot be placed even
        after preemption (it backs off)."""
        while True:
            r = self._next_candidate(tick, now)
            if r is None:
                return
            placed = self._try_place(r, now)
            while not placed and self._preempt_for(r, tick):
                placed = self._try_place(r, now)
            if not placed:
                self._refuse(r, tick)
                return

    def _eff_priority(self, r: Request, now: float) -> int:
        """Priority used for ORDERING (not preemption rights): a request
        past its TTFT budget jumps every class.  Preemption compares raw
        classes only — an SLO boost must not let equals evict each other
        (that thrash is the livelock the strictness guard exists for)."""
        if (self.cfg.slo_ttft_ms and r.t_first is None
                and (now - r.t_arrival) * 1e3 > self.cfg.slo_ttft_ms):
            return -1
        return r.priority

    def _next_candidate(self, tick: int, now: float) -> Request | None:
        elig = [r for r in self.queue
                if r.arrival <= tick and r.next_try <= tick]
        if not elig:
            arrived = [r for r in self.queue if r.arrival <= tick]
            if arrived and not self.by_slot:
                # idle kick: every arrived request is backing off and
                # nothing runs — clear the backoffs rather than idle
                for r in arrived:
                    r.next_try = tick
                self._c["idle_kicks"].inc()
                elig = arrived
            else:
                return None
        best = min(elig, key=lambda r: (self._eff_priority(r, now),
                                        0 if r.state == PREEMPTED else 1,
                                        r.arrival, r.id))
        if self._eff_priority(best, now) < best.priority:
            self._c["slo_boosts"].inc()
        return best

    def _evict_to_fit(self, total: int, chain, matched: int) -> None:
        """The evict-only-if-it-helps guard from the pre-scheduler serve
        loop: reclaim LRU trie-only leaves exactly when block shortage is
        the refusal cause AND the reclaimable supply can close the gap."""
        layout = self.pool.layout
        n_full = matched // layout.block_size
        protect = frozenset(chain)
        need = layout.blocks_for(total) - n_full
        if (total <= layout.max_len and need > self.pool.num_free
                and self.pool.num_free
                + self.prefix.reclaimable(self.pool, protect) >= need):
            while not self.pool.can_admit(total, n_shared=n_full):
                if self.prefix.evict_lru(self.pool, protect=protect) is None:
                    break

    def _try_place(self, r: Request, now: float) -> bool:
        if r.id in self.pool.swapped:
            return self._try_restore_swap(r, now)
        prompt = np.asarray(r.prompt)
        total = r.total
        chain, matched = [], 0
        if self.prefix is not None and self.pool.free_slots():
            chain, matched = self.prefix.match(prompt, record=False)
            self._evict_to_fit(total, chain, matched)
        if chain:
            got = self.pool.admit_shared(matched, total, chain)
            if got is None:
                return False
            slot, cow = got
            for src, dst in cow:
                self.kv.copy_block(src, dst)
        else:
            slot = self.pool.admit(0, total)
            if slot is None:
                return False
        restored = r.state == PREEMPTED
        self._place(r, slot, matched, now)
        # recompute restore: the prompt re-prefills from the trie match
        # (bitwise by the chunk-grid invariant), then the already-delivered
        # tokens TEACHER-FORCE through the decode kernel without being
        # delivered again — decode rows must come from the decode path
        r.pf_pos = matched
        r.decoding = False
        r.replay = deque(r.out)
        r.cur = -1 if not restored else r.cur   # re-seeded at prompt end
        if restored:
            self._c["restores_recompute"].inc()
            self._instant("restored", r, {"mode": "recompute",
                                          "matched": matched})
        else:
            self._c["admissions"].inc()
            self._instant("admitted", r, {"slot": slot, "matched": matched})
            if self.prefix is not None:
                self.prefix.record(matched)     # one lookup per admission
        return True

    def _try_restore_swap(self, r: Request, now: float) -> bool:
        rec = self.pool.swapped[r.id]
        prompt = np.asarray(r.prompt)
        chain, matched = [], 0
        if self.prefix is not None and self.pool.free_slots():
            # a trie match shrinks the host write-back; the match may have
            # GROWN past the swapped prefill position while the victim was
            # out (donors finished) — swap_in accounts the max
            chain, matched = self.prefix.match(prompt, record=False)
            self._evict_to_fit(rec.budget, chain, matched)
        got = self.pool.swap_in(r.id, chain, matched)
        if got is None:
            return False
        slot, cow, rec = got
        for src, dst in cow:
            self.kv.copy_block(src, dst)
        f = matched // self.pool.layout.block_size
        nb = self.pool.layout.blocks_for(rec.n_tokens) if rec.n_tokens else 0
        rows = self._host_rows.pop(r.id, None)
        ids = self.pool.block_ids(slot)[f:nb]
        if len(ids):
            self.kv.write_blocks(ids, rows, f)
        self._place(r, slot, matched, now)
        if r.decoding:
            # resume exactly where the victim stopped: all plen+|out| rows
            # are back, cur was saved — no replay needed, bitwise trivially
            r.pf_pos = r.plen
        else:
            r.pf_pos = max(matched, rec.n_tokens)   # mid-prefill victim
        self._c["restores_swap"].inc()
        self._instant("restored", r, {"mode": "swap", "matched": matched})
        return True

    def _place(self, r: Request, slot: int, matched: int, now: float) -> None:
        self._unpin(r)
        self.queue.remove(r)
        r.slot = slot
        r.state = RUNNING
        r.matched = matched
        r.attempts = 0
        r.remaining = int(r.gen) - len(r.out)
        r.admit_seq = self.n_admitted
        self.n_admitted += 1
        self.prefill_tokens_saved += matched
        self.by_slot[slot] = r

    def _refuse(self, r: Request, tick: int) -> None:
        if not self.pool.active.any():
            # nothing running, nothing preemptible: a request the EMPTY
            # pool refuses can never fit (same terminal condition the
            # pre-scheduler loop raised on)
            total = (self.pool.swapped[r.id].budget
                     if r.id in self.pool.swapped else r.total)
            raise RuntimeError(
                f"request {r.id} ({total} tokens) can never fit the pool "
                f"({self.pool.layout.num_blocks - 1} blocks)")
        r.attempts += 1
        r.next_try = tick + min(
            self.cfg.backoff_base << min(r.attempts - 1, 5),
            self.cfg.backoff_cap)
        self.refused_ids.add(r.id)
        self._c["refusals"].inc()
        self._instant("refused", r, {"attempts": r.attempts,
                                     "next_try": r.next_try})

    # ----------------------------------------------------------- preemption
    def _preempt_for(self, r: Request, tick: int) -> bool:
        victims = [v for v in self.by_slot.values()
                   if v.priority > r.priority]
        if not victims:
            return False
        v = min(victims, key=lambda v: (-v.priority,
                                        int(self.pool.lengths[v.slot]),
                                        -v.slot))
        self.preempt(v, tick)
        return True

    def preempt(self, v: Request, tick: int,
                mode: str | None = None) -> str:
        """Evacuate RUNNING request `v`.  Tries the configured mode; swap
        falls back to recompute when the host tier cannot absorb the
        victim (graceful degradation, never a refusal).  Returns the mode
        actually used."""
        assert v.state == RUNNING
        mode = mode or self.cfg.preemption
        slot = v.slot
        used = "recompute"
        if mode == "swap" and self.pool.host_blocks:
            n = int(self.pool.lengths[slot])
            nb = self.pool.layout.blocks_for(n) if n else 0
            if nb <= self.pool.host_free:
                ids = self.pool.block_ids(slot)[:nb]
                rows = self.kv.read_blocks(ids) if nb else None
                rec = self.pool.swap_out(slot, v.id)
                assert rec is not None
                if rows is not None:
                    self._host_rows[v.id] = rows
                used = "swap"
        if used == "recompute":
            if self.prefix is not None:
                # steer LRU eviction away from the prompt chain the
                # restore will re-match (best-effort, DESIGN.md §12)
                chain, _ = self.prefix.match(np.asarray(v.prompt),
                                             record=False)
                if chain:
                    self.prefix.pin_chain(chain)
                    v.pinned = list(chain)
            self.pool.release(slot)
        self._c[f"preempts_{used}"].inc()
        self._classes.add(v.priority)
        self.metrics.inc(f"sched/class{v.priority}/preemptions")
        self._instant("preempted", v, {"mode": used})
        v.preemptions += 1
        v.state = PREEMPTED
        v.slot = None
        v.next_try = tick           # eligible immediately; sorts first
        del self.by_slot[slot]
        self.queue.append(v)
        return used

    def fail_running(self, slot: int, tick: int) -> Request:
        """Injected worker failure on `slot` (satellite: fault_tolerance
        wiring): the device state is deemed LOST, so the victim is always
        requeued through the recompute path — restore re-prefills and
        replays, bitwise-identical to the unfailed run."""
        v = self.by_slot[slot]
        self.preempt(v, tick, mode="recompute")
        self._c["failures"].inc()
        self._instant("failed", v, {"slot": slot, "tick": tick})
        return v

    def _unpin(self, r: Request) -> None:
        if r.pinned:
            self.prefix.unpin_chain(r.pinned)
            r.pinned = None

    # ------------------------------------------------------------------ SLO
    def prefill_quota(self, base_tokens: int) -> int:
        """Per-step prefill token allowance under the ITL SLO.  Chunked-
        prefill interference is the knob: over-budget recent delivered ITL
        shrinks the prefill share proportionally (floor one token — the
        progress guarantee).  Chunk SHAPES and the global chunk grid are
        untouched, so outputs stay bitwise; only how many chunks run per
        step changes."""
        if not self.cfg.slo_itl_ms or len(self._itl_recent) < 8:
            return base_tokens
        p50 = float(np.median(np.asarray(self._itl_recent)))
        if p50 <= self.cfg.slo_itl_ms:
            return base_tokens
        return max(1, int(base_tokens * max(0.25, self.cfg.slo_itl_ms / p50)))

    # ------------------------------------------------------------ reporting
    def class_stats(self) -> dict:
        """Per-priority-class latency tails:
        {class: {n, preemptions, ttft_p50_ms, ttft_p99_ms, itl_p50_ms,
        itl_p99_ms}} — the BENCH_serve.json payload.

        Read straight from the registry's per-class histograms — the same
        instruments ``--metrics-out`` exports (sched/class{c}/ttft_ms,
        .../itl_ms), so the summary's tails and the archived snapshot can
        never disagree.  Resolution is the histogram contract: exact
        nearest-rank percentile of values quantized within ~1%
        (tests/test_telemetry.py pins it against exact percentiles)."""
        out = {}
        for cls in sorted(self._classes):
            ttft = self.metrics.histogram(f"sched/class{cls}/ttft_ms")
            itl = self.metrics.histogram(f"sched/class{cls}/itl_ms")
            out[cls] = {
                "n": self.metrics.counter(f"sched/class{cls}/done").value,
                "preemptions": self.metrics.counter(
                    f"sched/class{cls}/preemptions").value,
                "ttft_p50_ms": ttft.percentile(50),
                "ttft_p99_ms": ttft.percentile(99),
                "itl_p50_ms": itl.percentile(50),
                "itl_p99_ms": itl.percentile(99),
            }
        return out

    def stats(self) -> dict:
        out = dict(self.counters)
        out["preemptions"] = (out["preempts_swap"]
                              + out["preempts_recompute"])
        out["queued"] = len(self.queue)
        out["running"] = len(self.by_slot)
        out["done"] = len(self.done)
        return out
