"""Zero-dependency serving telemetry core (DESIGN.md §15).

Three primitives, stdlib-only so they import (and stay cheap) everywhere
in the runtime — kernels, scheduler, pool, serve loop:

  MetricsRegistry   process-wide counters / gauges / log-bucketed
                    histograms.  Replaces the ad-hoc stat dicts and the
                    duplicated percentile math that used to live in
                    runtime/scheduler.py and launch/serve.py: every
                    subsystem writes named instruments into one registry
                    and the serve summary / ``--metrics-out`` render from
                    ONE snapshot.

  Tracer            per-request lifecycle + per-launch span events in a
                    BOUNDED ring buffer (overflow drops the oldest event,
                    never grows), exported as Chrome trace-event JSON
                    (``--trace-out``, loadable in Perfetto / chrome
                    about:tracing).

  KernelProfiler    opt-in per-launch attention-kernel timing hook.
                    ``core.attn_spec.attn_entry`` — the single choke
                    point every jitted attention entry goes through —
                    consults :func:`profiler` and, when one is installed,
                    times the launch with ``block_until_ready`` and tags
                    it with the AttnSpec + argument geometry.  The
                    roofline join lives in ``launch/obs.py``.

Histogram contract (the part tests pin): values are QUANTIZED at record
time onto log-spaced buckets (geometric midpoint representative, relative
error <= ``rel_err``); ``merge`` is plain bucket-count addition, so it is
exactly associative and commutative; ``percentile`` is the EXACT
nearest-rank percentile of the quantized multiset.  Deterministic,
mergeable, bounded-memory — the properties the scheduler's per-class
latency tails and CI-archived snapshots need.

The telemetry invariant (enforced by tests + BENCH_obs.json): recording
never influences served tokens — telemetry-on output is bitwise identical
to telemetry-off — and the default-sampling overhead stays <= 2% of
decode throughput.
"""
from __future__ import annotations

import json
import math
import os
import time
from collections import deque
from contextlib import contextmanager

# version stamp for --metrics-out / --trace-out consumers; bump on any
# field reshape so CI archives are never silently misread
OBS_SCHEMA_VERSION = 1


# ---------------------------------------------------------------- metrics
class Counter:
    """Monotone event count.  ``incs`` tracks the number of ``inc`` calls
    (not the value) — the overhead-accounting input for BENCH_obs."""
    __slots__ = ("value", "incs")

    def __init__(self):
        self.value = 0
        self.incs = 0

    def inc(self, n: int = 1) -> None:
        self.value += n
        self.incs += 1


class Gauge:
    """Last-set value (pool occupancy, queue depth, ...)."""
    __slots__ = ("value", "sets")

    def __init__(self):
        self.value = 0.0
        self.sets = 0

    def set(self, v) -> None:
        self.value = float(v)
        self.sets += 1


class Histogram:
    """Mergeable log-bucketed histogram with exact quantized percentiles.

    Record-time quantization: value ``v > 0`` lands in bucket
    ``i = floor(log(v) / log(gamma))`` with ``gamma = (1+rel_err)/(1-rel_err)``
    and reads back as the geometric bucket midpoint ``gamma**(i+0.5)`` —
    relative error at most ``sqrt(gamma) - 1`` (~``rel_err``).  Values
    ``<= 0`` land in a dedicated zero bucket reading back as ``0.0``.

    All state is integer bucket counts plus exact float min/max, so
    ``merge`` (bucket-wise addition) is exactly associative/commutative
    and a merged histogram's percentiles equal the percentiles of the
    concatenated sample streams — the property tests/test_telemetry.py
    drives.  ``sum``/``mean`` are derived from the quantized counts (same
    ~rel_err contract)."""
    __slots__ = ("rel_err", "_gamma", "_lg", "counts", "zero", "vmin",
                 "vmax")

    def __init__(self, rel_err: float = 0.01):
        assert 0 < rel_err < 1, f"rel_err must be in (0, 1), got {rel_err}"
        self.rel_err = rel_err
        self._gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._lg = math.log(self._gamma)
        self.counts: dict[int, int] = {}
        self.zero = 0
        self.vmin = math.inf
        self.vmax = -math.inf

    @classmethod
    def from_values(cls, values, rel_err: float = 0.01) -> "Histogram":
        h = cls(rel_err)
        for v in values:
            h.record(v)
        return h

    def record(self, v) -> None:
        v = float(v)
        if v <= 0.0:
            self.zero += 1
            v = 0.0
        else:
            i = math.floor(math.log(v) / self._lg)
            self.counts[i] = self.counts.get(i, 0) + 1
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def _rep(self, i: int) -> float:
        return self._gamma ** (i + 0.5)

    @property
    def count(self) -> int:
        return self.zero + sum(self.counts.values())

    @property
    def sum(self) -> float:
        # derived from counts in sorted-bucket order: deterministic for a
        # given bucket multiset, so merged histograms agree bit-for-bit
        return math.fsum(n * self._rep(i)
                         for i, n in sorted(self.counts.items()))

    @property
    def mean(self) -> float:
        n = self.count
        return self.sum / n if n else 0.0

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile of the quantized multiset:
        the smallest recorded (quantized) value with cumulative count
        >= ceil(q/100 * n).  0.0 on an empty histogram."""
        n = self.count
        if n == 0:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * n))
        if rank <= self.zero:
            return 0.0
        acc = self.zero
        for i, c in sorted(self.counts.items()):
            acc += c
            if acc >= rank:
                return self._rep(i)
        return self._rep(max(self.counts))          # q > 100 clamps to max

    def merge(self, other: "Histogram") -> "Histogram":
        """Bucket-count addition into a NEW histogram (operands
        untouched).  Exactly associative and commutative: every field is
        either an integer sum or a min/max."""
        assert self.rel_err == other.rel_err, \
            f"histogram resolution mismatch: {self.rel_err} vs {other.rel_err}"
        out = Histogram(self.rel_err)
        out.counts = dict(self.counts)
        for i, c in other.counts.items():
            out.counts[i] = out.counts.get(i, 0) + c
        out.zero = self.zero + other.zero
        out.vmin = min(self.vmin, other.vmin)
        out.vmax = max(self.vmax, other.vmax)
        return out

    def to_dict(self) -> dict:
        n = self.count
        return {"count": n, "sum": self.sum,
                "min": self.vmin if n else 0.0,
                "max": self.vmax if n else 0.0,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99), "rel_err": self.rel_err}


class MetricsRegistry:
    """Named instrument store: ``counter``/``gauge``/``histogram`` are
    create-or-get (a name maps to exactly one instrument kind — reusing a
    name across kinds is a bug and asserts).  ``snapshot()`` is the one
    read path the serve summary, ``--metrics-out`` and the tests share.

    Single-threaded by design (the serve loop is one thread); no locks."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def _fresh(self, name: str, table: dict) -> None:
        for other in (self._counters, self._gauges, self._hists):
            assert other is table or name not in other, \
                f"metric {name!r} already registered as another kind"

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            self._fresh(name, self._counters)
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            self._fresh(name, self._gauges)
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, rel_err: float = 0.01) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            self._fresh(name, self._hists)
            h = self._hists[name] = Histogram(rel_err)
        return h

    # conveniences for cold paths (hot loops hold the instrument object)
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def observe(self, name: str, v) -> None:
        self.histogram(name).record(v)

    def value(self, name: str):
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            return self._gauges[name].value
        raise KeyError(name)

    def op_count(self) -> int:
        """Total recording operations since construction — the
        numerator of the BENCH_obs overhead accounting."""
        return (sum(c.incs for c in self._counters.values())
                + sum(g.sets for g in self._gauges.values())
                + sum(h.count for h in self._hists.values()))

    def snapshot(self) -> dict:
        """One schema-versioned dict of everything recorded.  Plain JSON
        types only — json.dumps(snapshot) must always succeed."""
        return {
            "schema_version": OBS_SCHEMA_VERSION,
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._hists.items())},
        }


_DEFAULT_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry — for library code with no
    handle.  The serve loop builds a fresh registry per run instead, so
    back-to-back runs in one process never mix counters."""
    return _DEFAULT_REGISTRY


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    global _DEFAULT_REGISTRY
    prev, _DEFAULT_REGISTRY = _DEFAULT_REGISTRY, reg
    return prev


# ---------------------------------------------------------------- tracing
class Tracer:
    """Bounded-memory span/instant recorder exporting Chrome trace-event
    JSON.  Events live in a ring buffer (``capacity`` newest events;
    overflow increments ``dropped`` and evicts the oldest — recording
    never allocates past the ring).  Timestamps are microseconds on one
    monotonic clock (``time.perf_counter`` by default) relative to tracer
    construction; ``to_events`` sorts by ``ts``, so exported timestamps
    are non-decreasing even though spans are recorded at END time.

    Event kinds (Chrome trace-event ``ph``):
      "X"  complete span  (ts = start, dur = duration) — chunks, steps
      "i"  instant        — request lifecycle edges
      "M"  metadata       — process name, emitted once at export
    """

    def __init__(self, capacity: int = 65536, clock=time.perf_counter):
        assert capacity >= 1
        self.capacity = capacity
        self._clock = clock
        self._t0 = clock()
        self._buf: deque = deque(maxlen=capacity)
        self.recorded = 0
        self.pid = os.getpid()

    @property
    def dropped(self) -> int:
        return self.recorded - len(self._buf)

    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _push(self, ev: tuple) -> None:
        self._buf.append(ev)
        self.recorded += 1

    def instant(self, name: str, tid: int = 0, args: dict = None) -> None:
        self._push(("i", name, self.now_us(), int(tid), 0.0, args))

    def complete(self, name: str, t0_us: float, tid: int = 0,
                 args: dict = None) -> None:
        """Record a span that STARTED at ``t0_us`` (from :meth:`now_us`)
        and ends now."""
        self._push(("X", name, t0_us, int(tid),
                    max(0.0, self.now_us() - t0_us), args))

    @contextmanager
    def span(self, name: str, tid: int = 0, args: dict = None):
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, t0, tid=tid, args=args)

    def to_events(self) -> list:
        """Chrome trace-event dicts, sorted by timestamp.  Every event
        carries the required ``name``/``ph``/``ts``/``pid``/``tid``."""
        events = [{"name": "process_name", "ph": "M", "ts": 0.0,
                   "pid": self.pid, "tid": 0,
                   "args": {"name": "repro-serve"}}]
        for ph, name, ts, tid, dur, args in sorted(self._buf,
                                                   key=lambda e: e[2]):
            ev = {"name": name, "ph": ph, "ts": ts, "pid": self.pid,
                  "tid": tid}
            if ph == "X":
                ev["dur"] = dur
            if ph == "i":
                ev["s"] = "t"                      # thread-scoped instant
            if args:
                ev["args"] = args
            events.append(ev)
        return events

    def export(self, path: str) -> dict:
        """Write ``{"traceEvents": [...]}`` JSON; returns summary stats."""
        events = self.to_events()
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"schema_version": OBS_SCHEMA_VERSION,
                             "recorded": self.recorded,
                             "dropped": self.dropped}}
        with open(path, "w") as f:
            json.dump(doc, f)
        return {"events": len(events), "recorded": self.recorded,
                "dropped": self.dropped, "path": path}


# ------------------------------------------------------- kernel profiling
class KernelProfiler:
    """Opt-in per-launch kernel timing (``--profile-kernels N``).

    ``attn_entry`` calls :meth:`want` once per entry invocation; every
    ``sample_every``-th launch is run to completion under
    ``block_until_ready`` and recorded as (entry name, spec tag, argument
    geometry) -> (launch count, total seconds).  Aggregation happens at
    record time, so memory is bounded by the number of DISTINCT
    geometries (a handful per serve run), not the launch count.

    Forcing completion per sampled launch defeats async dispatch — that
    is the point (true per-launch wall time) and why the profiler is
    opt-in rather than part of default-sampling telemetry."""

    def __init__(self, sample_every: int = 1):
        assert sample_every >= 1
        self.sample_every = sample_every
        self._tick = 0
        self.sampled = 0
        # (name, tag, geometry) -> [count, total_seconds]
        self.records: dict[tuple, list] = {}

    def want(self) -> bool:
        self._tick += 1
        return (self._tick - 1) % self.sample_every == 0

    def record(self, name: str, tag: str, geometry: tuple,
               dt_s: float) -> None:
        self.sampled += 1
        rec = self.records.setdefault((name, tag, geometry), [0, 0.0])
        rec[0] += 1
        rec[1] += dt_s


_PROFILER: KernelProfiler = None


def profiler() -> KernelProfiler:
    """The installed kernel profiler, or None (the default: attn_entry's
    hook is a single ``is None`` check per call)."""
    return _PROFILER


def set_profiler(p: KernelProfiler) -> KernelProfiler:
    global _PROFILER
    prev, _PROFILER = _PROFILER, p
    return prev
