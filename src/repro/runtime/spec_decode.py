"""Draft proposers + greedy acceptance for speculative decoding
(DESIGN.md §14).

The serve loop's draft-then-verify step is split in three:

  1. a cheap host-side DRAFT proposer (this module) guesses k-1 tokens
     continuing the committed stream;
  2. ONE chunked-prefill-shaped verify pass (model.verify_step) scores
     [cur, d_1, .., d_{k-1}] against the paged pool in a single launch;
  3. greedy ACCEPTANCE (:func:`accept_greedy`, this module) keeps the
     longest prefix of drafts that match the model's own argmax chain,
     and the scheduler rewinds the rejected tail with
     BlockPool.truncate(..., free_blocks=False).

Proposers are deliberately model-free or near-free: speculation only pays
when drafting is much cheaper than a decode step, and greedy acceptance
makes ANY proposer output-safe — a bad draft costs wasted verify columns,
never a wrong token (the accepted stream is exactly the one-at-a-time
greedy stream, which tests/test_spec_decode.py pins bitwise).
"""
from __future__ import annotations

import numpy as np

DRAFT_KINDS = ("ngram", "head")


def ngram_propose(history, k: int, max_n: int = 4) -> list:
    """Propose ``k`` tokens continuing ``history`` by longest-suffix n-gram
    match: for n = max_n..1, find the MOST RECENT earlier occurrence of the
    length-n suffix and propose the tokens that followed it (repetitive
    decode traces — loops, boilerplate — make this accurate and free).
    Falls back to repeating the last token.  O(n · L) per candidate n via
    a vectorized window compare; history lengths here are serve-loop
    transcripts, not corpora."""
    h = np.asarray(history, dtype=np.int64).ravel()
    L = int(h.size)
    assert L >= 1 and k >= 1
    for n in range(min(max_n, L - 1), 0, -1):
        suf = h[L - n:]
        # windows[i] == h[i:i+n]; candidate starts exclude the suffix itself
        windows = np.lib.stride_tricks.sliding_window_view(h, n)[: L - n]
        hits = np.nonzero((windows == suf[None, :]).all(axis=1))[0]
        if hits.size:
            j = int(hits[-1]) + n             # continuation of latest match
            cont = h[j: j + k]
            if cont.size:
                out = cont.tolist()
                while len(out) < k:           # match ran into the suffix
                    out.append(out[-1])
                return [int(t) for t in out]
    return [int(h[-1])] * k


class HeadDraft:
    """Self-draft "head" proposer stand-in: a greedy next-token table from
    embedding similarity, ``next(t) = argmax_{t' != t} E[t] · E[t']``,
    chained k times.  It is the shape of a learned draft head (one matmul
    per token, no KV cache) without training machinery; fp8 pools are
    declared unsupported (launch/serve.py validates the flag combo) to
    exercise the CLI combo-validation path."""

    def __init__(self, embed):
        e = np.asarray(embed, np.float32)
        sim = e @ e.T
        np.fill_diagonal(sim, -np.inf)        # a real chain, not cur repeated
        self.table = np.argmax(sim, axis=1).astype(np.int64)

    def propose(self, history, k: int, **_) -> list:
        t = int(np.asarray(history).ravel()[-1])
        out = []
        for _ in range(k):
            t = int(self.table[t])
            out.append(t)
        return out


def make_drafter(kind: str, params, *, metrics=None):
    """Proposer factory for the serve loop: ``propose(history, k) -> [k]``.
    ``params`` is the model param pytree (the head drafter reads the
    embedding table; ngram needs nothing).  With a ``metrics`` registry
    the proposer is wrapped to count draft calls and histogram proposal
    lengths (``spec/draft_calls`` / ``spec/draft_len``) — the proposals
    themselves are untouched."""
    if kind == "ngram":
        fn = ngram_propose
    elif kind == "head":
        fn = HeadDraft(params["embed"]).propose
    else:
        raise ValueError(
            f"unknown draft kind {kind!r} (want one of {DRAFT_KINDS})")
    if metrics is None:
        return fn
    calls = metrics.counter("spec/draft_calls")
    lens = metrics.histogram("spec/draft_len")

    def counted(history, k, **kw):
        out = fn(history, k, **kw)
        calls.inc()
        lens.record(len(out))
        return out
    return counted


def accept_greedy(drafts, preds) -> tuple:
    """Greedy acceptance rule (DESIGN.md §14).

    ``drafts``: the k-1 proposed tokens d_1..d_{k-1}; ``preds``: the k
    verify-pass argmaxes n_0..n_{k-1}, where n_i is the model's greedy
    next token after verify row i (row 0 is the committed token ``cur``).
    Draft d_{i+1} is correct iff it equals n_i AND every earlier draft was
    accepted (a later match after a miss scored against a wrong context).
    Returns ``(accepted, next_token)``: the accepted draft count and the
    model's continuation after the last accepted row — exactly the tokens
    one-at-a-time greedy decode would have produced."""
    preds = [int(p) for p in np.asarray(preds).ravel()]
    a = 0
    for d in drafts:
        if int(d) == preds[a]:
            a += 1
        else:
            break
    return a, preds[a]
