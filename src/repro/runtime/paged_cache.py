"""Paged KV-cache subsystem: block-pool allocator + block-table array ops.

The serving cache is a *pool* of fixed-size KV blocks (pages) shared by all
sequences, FlashMLA/vLLM-style, instead of a dense ``[B, max_len]`` slab:

    pool        [num_blocks, block_size, *feat]   (per layer; jnp, on device)
    block_table [B, max_blocks]  int32            (shared across layers)
    lengths     [B]              int32            (tokens written per slot)

Sequence ``b``'s token at logical position ``t`` lives at
``pool[block_table[b, t // block_size], t % block_size]``.  Block ids are
granted by a host-side free-list (:class:`BlockPool`); the block *table* is
what the paged Pallas kernels prefetch to gather KV through (see
``kernels/etap/etap.py``).

Allocator invariants (DESIGN.md §8):
  · Block 0 is the reserved NULL block: never allocated, every padded /
    released table entry points at it.  Inactive batch slots therefore
    write their (ignored) decode rows into block 0 and read back finite
    garbage that is masked by ``length`` — no branches anywhere on device.
  · Admission reserves blocks for the request's full budget
    (prompt + max new tokens) up front, so a decode step can never fail
    mid-flight; running out of blocks is an *admission refusal*, which the
    continuous-batching scheduler (launch/serve.py) handles by queueing.
  · ``release`` returns blocks to the free list and zeroes the table row,
    so ids are recycled across requests (tests/test_paged.py proves
    reuse-after-release and the refusal path).

Prefix sharing (DESIGN.md §10): every non-null block carries a REFCOUNT —
one reference per batch slot mapping it plus one for the prefix-cache trie
(runtime/prefix_cache.py) when the block is cached.  :meth:`BlockPool.admit_shared`
maps an already-computed prefix chain into a new slot's table with a
refcount bump instead of a free-list draw (its prefill is SKIPPED), and
copy-on-write is eager-at-admission: a cached prefix ending mid-block gets
its partial tail block copied into a fresh private block before any token
is written, so in-flight writes never need to allocate (the no-mid-flight-
OOM invariant survives sharing).  ``release`` drops one reference per
chain block; only blocks hitting refcount zero return to the free list —
trie-cached prompt blocks live on as the LRU-evictable cached set.
Conservation (checked by :meth:`BlockPool.check_conservation`): a non-null
block is on the free list iff its refcount is zero, and writes may only
touch refcount-1 (exclusively owned) blocks.

Rollback and the host swap tier (DESIGN.md §12): :meth:`BlockPool.truncate`
is the invariant-safe rollback primitive — it shrinks a slot's block chain
(and, because the sz scale pools page with the code pools, its quantized
twin) to a token boundary, freeing tail blocks through the same
``unref_block`` path release uses, so trie-cached blocks survive and
free ⟺ ref == 0 conservation holds at every intermediate state.  ``release``
is ``truncate(slot, 0)`` plus slot teardown.  On top of it the pool is a
TWO-TIER HBM/host hierarchy: ``swap_out`` moves a preempted slot's written
blocks into a host-RAM tier (a second free-list of ``host_blocks`` ids; the
actual bytes are read off-device by the caller BEFORE the call and restored
by it after ``swap_in``), releasing every device block.  Swap records hold
NO device references — a swapped request's trie-cached prompt blocks are
owned by the trie alone, and cancelling a swapped request frees host ids
only (the double-unref edge tests/test_scheduler.py pins).

Quantized layouts (DESIGN.md §11): the pool may store KV rows as int8 (or
fp8 e4m3) codes with a per-ROW affine (scale, zero-point) pair kept in a
parallel ``sz`` pool of shape ``[num_blocks, block_size, *lead, 2]``.  The
row is the quantization granule — one (scale, zp) per written token (per
kv head for GQA pools) — because every write path (append_rows,
append_chunk) touches whole rows and only rows: a PER-BLOCK scale would
have to re-quantize previously-written rows whenever a later append raised
the block's max, destroying the bitwise-stability the prefix cache depends
on.  Scales live at block granularity in STORAGE (the sz pool pages with
the code pool, so :func:`copy_block` and COW move codes and scales
together), while the numeric granule is the row.  Dequantization is fused
inside the ETAP Pallas kernels (kernels/etap/etap.py): codes and scales
stream per pool block and are expanded in registers before the dot;
softmax statistics and accumulation stay fp32 (§6).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

NULL_BLOCK = 0

# Quantized KV layouts (DESIGN.md §11).  "fp" is the config dtype
# passthrough; "int8" is asymmetric per-row affine; "fp8" emulates the
# H20's e4m3 format via jnp.float8_e4m3fn (symmetric — fp8 has a sign
# bit, so the zero-point is pinned to 0 and only the scale is live).
KV_LAYOUTS = ("fp", "int8", "fp8")
HAS_FP8 = hasattr(jnp, "float8_e4m3fn")
F8_MAX = 448.0                    # e4m3fn finite max (no inf encoding)
INT8_MAX = 127.0


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of a paged cache."""
    block_size: int            # tokens per KV block (page)
    num_blocks: int            # pool size, INCLUDING the reserved null block
    max_blocks: int            # block-table width (max logical blocks/seq)

    def __post_init__(self):
        assert self.block_size >= 1 and self.max_blocks >= 1
        assert self.num_blocks >= 2, "need at least null block + one real block"

    @property
    def max_len(self) -> int:
        return self.max_blocks * self.block_size

    def blocks_for(self, n_tokens: int) -> int:
        return max(1, -(-int(n_tokens) // self.block_size))


def layout_for(batch_slots: int, max_len: int, block_size: int = 64,
               spare_blocks: int = 0) -> PagedLayout:
    """A layout that can hold `batch_slots` full-length sequences (+spares)."""
    max_blocks = max(1, -(-int(max_len) // block_size))
    return PagedLayout(block_size=block_size,
                       num_blocks=1 + batch_slots * max_blocks + spare_blocks,
                       max_blocks=max_blocks)


@dataclasses.dataclass
class SwapRecord:
    """Accounting for one preempted sequence resident in the host tier.

    ``host_ids`` hold one host-tier block id per WRITTEN logical block (the
    tail of the reservation that held no rows is re-reserved at swap_in,
    not stored); ``n_tokens`` is how many rows the host copies carry and
    ``budget`` the original reserved token budget, so restoration re-admits
    with exactly the guarantees the first admission had.  A record holds NO
    device references: the victim's trie-cached prompt blocks belong to the
    trie alone after swap_out, and discarding a record (cancel) returns
    host ids only."""
    key: object
    host_ids: list
    n_tokens: int
    budget: int


class BlockPool:
    """Host-side free-list allocator over `layout.num_blocks` KV blocks,
    owning the block table and per-slot lengths for `batch_slots` slots.
    ``host_blocks`` > 0 adds the host swap tier (DESIGN.md §12)."""

    def __init__(self, layout: PagedLayout, batch_slots: int,
                 host_blocks: int = 0, *, metrics=None):
        self.layout = layout
        self.batch_slots = batch_slots
        # optional MetricsRegistry (runtime/telemetry.py): swap-tier
        # traffic counters at swap_out/swap_in; occupancy gauges go
        # through :meth:`observe` (accounting only — never control flow)
        self.metrics = metrics
        # pop order low→high keeps tables human-readable in tests/logs
        self._free = deque(range(1, layout.num_blocks))      # 0 = null block
        self.table = np.zeros((batch_slots, layout.max_blocks), np.int32)
        self.lengths = np.zeros((batch_slots,), np.int32)
        self.active = np.zeros((batch_slots,), bool)
        # per-block reference count: one per slot mapping the block + one
        # when the prefix-cache trie holds it.  free ⟺ ref == 0.
        self.ref = np.zeros((layout.num_blocks,), np.int32)
        # logical block chain per slot: shared prefix blocks first (mapped
        # by admit_shared, refcount-bumped), then freshly allocated blocks
        self._chain: list[list[int]] = [[] for _ in range(batch_slots)]
        self._nshared = np.zeros((batch_slots,), np.int32)
        self._budget = np.zeros((batch_slots,), np.int32)    # reserved tokens
        # host swap tier: a second free-list of host-RAM block ids.  The
        # pool accounts capacity; the KV bytes live with the caller (read
        # off-device before swap_out, written back after swap_in).
        self.host_blocks = int(host_blocks)
        self._host_free = deque(range(self.host_blocks))
        self.swapped: dict = {}                  # key -> SwapRecord

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def host_free(self) -> int:
        return len(self._host_free)

    def free_slots(self) -> list[int]:
        return [b for b in range(self.batch_slots) if not self.active[b]]

    def free_ids(self) -> set[int]:
        """Snapshot of the free-list block ids — the public inspection
        surface for invariant tests (free ⟺ refcount 0 conservation);
        mutation still goes through admit/extend/append/release."""
        return set(self._free)

    def budget(self, slot: int) -> int:
        """Reserved token budget of `slot` (0 when torn down): the cap
        ``extend``/``append`` enforce and ``truncate`` rewinds to."""
        return int(self._budget[slot])

    def can_admit(self, max_total_len: int, n_shared: int = 0) -> bool:
        """Admission predicate: a free batch slot AND enough free blocks to
        reserve the request's whole token budget.  ``n_shared`` counts FULL
        prefix blocks only — blocks mapped from the prefix cache by a
        refcount bump with no free-list draw.  A chain whose tail block is
        PARTIAL (the shared prefix ends mid-block) contributes
        ``matched_tokens // block_size``, NOT ``len(chain)``: the partial
        donor block is never mapped — its logical position is taken by a
        freshly drawn eager-COW copy target, which must be counted against
        the free list *before* admission succeeds (the one-block-short
        refusal boundary, tests/test_paged.py)."""
        if max_total_len > self.layout.max_len:
            return False
        need = self.layout.blocks_for(max_total_len) - int(n_shared)
        return bool(self.free_slots()) and need <= self.num_free

    def admit(self, prompt_len: int, max_total_len: int) -> int | None:
        """Reserve a slot + blocks for `max_total_len` tokens; returns the
        slot id, or None (admission refusal — the caller keeps the request
        queued).  `prompt_len` rows are accounted as already written (the
        test/bench path that packs a prefilled dense cache via
        :func:`dense_to_paged`).  prompt_len 0 is a COLD admission: blocks
        are reserved but nothing is written yet — the chunked-prefill
        scheduler grows the length via :func:`extend` as it appends prompt
        chunks (launch/serve.py, DESIGN.md §9)."""
        got = self.admit_shared(prompt_len, max_total_len, ())
        return None if got is None else got[0]

    def admit_shared(self, prompt_len: int, max_total_len: int,
                     shared_ids) -> tuple | None:
        """Admission with a cached prefix: map `shared_ids` — the physical
        chain holding the request's first `prompt_len` tokens, found by the
        prefix-cache trie — into the new slot's table with a refcount bump
        per block, and allocate fresh blocks only for the remaining budget.
        The mapped prefix is never prefilled again (its tokens are
        accounted as written); chunked prefill resumes at offset
        `prompt_len`.

        Copy-on-write on divergence: when `prompt_len` ends MID-block, the
        chain's partial tail block is still the donor's (its later rows
        belong to the donor's continuation), so it is NOT mapped — the
        first fresh block takes its logical position and the pair is
        returned for the caller to device-copy (models.model.copy_paged_block)
        BEFORE any chunk is appended.  The copy happens at admission, not at
        write time, so admission still reserves the whole budget up front
        and in-flight steps never allocate.  The donor block must be kept
        referenced by the caller (trie or donor slot) until the copy runs.

        Returns (slot, cow) with cow = [] or [(src_block, dst_block)], or
        None (refusal)."""
        assert 0 <= prompt_len <= max_total_len and max_total_len >= 1
        shared_ids = [int(b) for b in shared_ids]
        n_full = prompt_len // self.layout.block_size
        if shared_ids:
            assert prompt_len >= 1
            assert len(shared_ids) == self.layout.blocks_for(prompt_len), \
                "shared chain must cover exactly the prompt_len prefix"
        else:
            n_full = 0                       # nothing to map without a chain
        if not self.can_admit(max_total_len, n_shared=n_full):
            return None
        slot = self.free_slots()[0]
        need = self.layout.blocks_for(max_total_len)
        reused = shared_ids[:n_full]
        fresh = [self._free.popleft() for _ in range(need - n_full)]
        cow = []
        if len(shared_ids) > n_full:         # prefix ends mid-block: COW
            cow.append((shared_ids[n_full], fresh[0]))
        for bid in reused:
            assert self.ref[bid] > 0, "shared block must be live (trie/slot)"
            self.ref[bid] += 1
        for bid in fresh:
            assert self.ref[bid] == 0
            self.ref[bid] = 1
        chain = reused + fresh
        self._chain[slot] = chain
        self._nshared[slot] = len(reused)
        self.table[slot] = NULL_BLOCK
        self.table[slot, :len(chain)] = chain
        self.lengths[slot] = prompt_len
        self._budget[slot] = max_total_len
        self.active[slot] = True
        return slot, cow

    def block_ids(self, slot: int) -> np.ndarray:
        """Physical block chain of `slot` in logical order: shared prefix
        blocks (if any) first, then the freshly allocated blocks."""
        return np.asarray(self._chain[slot], np.int32)

    def ref_block(self, bid: int) -> None:
        """Take an external (prefix-trie) reference on a live block."""
        assert bid != NULL_BLOCK and self.ref[bid] > 0
        self.ref[bid] += 1

    def unref_block(self, bid: int) -> bool:
        """Drop one reference; the block returns to the free list when the
        count hits zero.  Returns True iff the block was freed."""
        assert bid != NULL_BLOCK and self.ref[bid] > 0
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            self._free.append(bid)
            return True
        return False

    def _assert_writable(self, slot: int, lo_tok: int, hi_tok: int) -> None:
        """Writes may only touch exclusively-owned blocks (refcount 1): a
        shared or trie-cached block is read-only for every mapper — the COW
        copy at admission guarantees the write region is always private."""
        bs = self.layout.block_size
        for bid in self._chain[slot][lo_tok // bs:hi_tok // bs + 1]:
            assert self.ref[bid] == 1, \
                f"COW violation: write into shared block {bid} " \
                f"(refcount {int(self.ref[bid])})"

    def append(self, slot: int) -> None:
        """Account one generated token for `slot` (the device-side write is
        :func:`append_rows`).  Never allocates: admission already reserved
        the full budget."""
        assert self.active[slot]
        assert self.lengths[slot] < self._budget[slot], \
            f"slot {slot} exceeded its reserved budget"
        self._assert_writable(slot, int(self.lengths[slot]),
                              int(self.lengths[slot]))
        self.lengths[slot] += 1

    def extend(self, slot: int, n: int) -> None:
        """Account `n` prompt tokens appended to `slot` in one prefill chunk
        (the device-side write is :func:`append_chunk`).  Never allocates:
        admission already reserved the full budget, so a chunk can never run
        out of blocks mid-prompt."""
        assert self.active[slot] and n >= 0
        assert self.lengths[slot] + n <= self._budget[slot], \
            f"slot {slot} chunk of {n} exceeds its reserved budget"
        if n:
            self._assert_writable(slot, int(self.lengths[slot]),
                                  int(self.lengths[slot]) + n - 1)
        self.lengths[slot] += n

    def truncate(self, slot: int, n_tokens: int, *,
                 free_blocks: bool = True) -> int:
        """Invariant-safe ROLLBACK (DESIGN.md §12): shrink `slot`'s chain
        to the `n_tokens` boundary.  Tail blocks beyond
        ``blocks_for(n_tokens)`` (all of them at 0) are dropped through
        :meth:`unref_block` — a trie-cached or slot-shared tail block
        survives at its remaining refcount, exactly like release — their
        table columns are nulled, and the slot's budget shrinks to the
        kept blocks' capacity (the slot may still fill the kept tail block
        without allocating, but growing past it needs a fresh admission).
        The sz scale pools shrink for free: they page with the code pools,
        and rows beyond the new length are masked by ``lengths`` on every
        read path.  A truncation landing MID-block keeps that boundary
        block; if it is shared (refcount > 1) it stays read-only and the
        device write guard still fires on any append into it.

        ``free_blocks=False`` is the pure LENGTH rollback (the speculative-
        decoding primitive, ROADMAP item 2): only ``lengths`` rewinds — the
        rejected tokens' rows become masked garbage — and the reservation
        is untouched, so decoding continues under the no-mid-flight-
        allocation guarantee.

        Returns the number of blocks freed to the free list."""
        assert self.active[slot]
        n_tokens = int(n_tokens)
        assert 0 <= n_tokens <= int(self.lengths[slot]), \
            f"truncate to {n_tokens} past written length " \
            f"{int(self.lengths[slot])}"
        if not free_blocks:
            self.lengths[slot] = n_tokens
            return 0
        keep = self.layout.blocks_for(n_tokens) if n_tokens else 0
        chain = self._chain[slot]
        assert keep <= len(chain)
        freed = 0
        for bid in reversed(chain[keep:]):
            freed += bool(self.unref_block(bid))
        self._chain[slot] = chain[:keep]
        self.table[slot, keep:] = NULL_BLOCK
        self.lengths[slot] = n_tokens
        self._nshared[slot] = min(int(self._nshared[slot]), keep)
        self._budget[slot] = keep * self.layout.block_size
        return freed

    def release(self, slot: int) -> None:
        """Drop one reference per chain block and null the slot's table row
        (``truncate(slot, 0)`` + slot teardown).  Blocks hitting refcount
        zero return to the free list; blocks the prefix-cache trie (or
        another slot) still references stay allocated — that is what turns
        a finished request's prompt blocks into the LRU-evictable cached
        set instead of freeing them."""
        assert self.active[slot]
        # audit (falsifiable): columns BEYOND the chain must already be
        # null — admission nulls the row before writing the chain and no
        # write path touches columns past it, so a stale physical id there
        # means some mutation scribbled the table out of band.  The
        # truncate below then guarantees a released row can never surface
        # a stale mapping through device_views() (tests/test_paged.py).
        assert (self.table[slot, len(self._chain[slot]):]
                == NULL_BLOCK).all(), "stale ids beyond the slot's chain"
        self.truncate(slot, 0)
        self._nshared[slot] = 0
        self._budget[slot] = 0
        self.active[slot] = False

    # ------------------------------------------------------ host swap tier
    def can_swap_out(self, slot: int) -> bool:
        """Whether the host tier can absorb `slot`'s written blocks."""
        n = int(self.lengths[slot])
        nb = self.layout.blocks_for(n) if n else 0
        return nb <= self.host_free

    def swap_out(self, slot: int, key) -> SwapRecord | None:
        """Evacuate `slot` to the host tier: reserve one host block per
        WRITTEN device block, record (key, host ids, written length,
        original budget), then fully release the slot — device blocks the
        trie still caches survive as the cached set, private tail blocks
        free.  Returns the record, or None when the host tier is full (the
        scheduler then falls back to drop-and-recompute preemption).

        The CALLER moves the bytes: it must copy the written blocks
        (``block_ids(slot)[:nb]``) off-device BEFORE calling — after this
        returns, freed device blocks may be re-allocated and overwritten
        at any time."""
        assert self.active[slot]
        assert key not in self.swapped, f"key {key!r} already swapped"
        n_tokens = int(self.lengths[slot])
        nb = self.layout.blocks_for(n_tokens) if n_tokens else 0
        if nb > self.host_free:
            return None
        host_ids = [self._host_free.popleft() for _ in range(nb)]
        rec = SwapRecord(key=key, host_ids=host_ids, n_tokens=n_tokens,
                         budget=int(self._budget[slot]))
        self.swapped[key] = rec
        self.release(slot)
        if self.metrics is not None and nb:
            self.metrics.inc("pool/swap_out_blocks", nb)
        return rec

    def swap_in(self, key, shared_ids=(), matched: int = 0):
        """Restore a swapped sequence into a fresh slot: re-admit with the
        record's ORIGINAL budget (``admit_shared`` — a trie match on the
        prompt maps `shared_ids` by refcount bump so only the unmatched
        blocks need host copies written back), account the restored rows,
        and return the host ids to the tier.  Returns
        ``(slot, cow, record)`` or None (admission refusal: the record is
        untouched and the scheduler retries later).

        The caller writes the bytes AFTER this returns: host copies of
        logical blocks ``[matched // block_size : blocks_for(n_tokens))``
        go into ``block_ids(slot)`` at those positions.  A trie match
        LONGER than the swapped length is fine (the trie grew while the
        request was out): the matched blocks already hold valid rows and
        the restored length is their maximum."""
        rec = self.swapped[key]
        matched = int(matched)
        got = self.admit_shared(matched, rec.budget, shared_ids)
        if got is None:
            return None
        slot, cow = got
        n_eff = max(matched, rec.n_tokens)
        if n_eff > matched:
            self.extend(slot, n_eff - matched)
        if self.metrics is not None and rec.host_ids:
            self.metrics.inc("pool/swap_in_blocks", len(rec.host_ids))
        self.swap_free(key)
        return slot, cow, rec

    def swap_free(self, key) -> SwapRecord:
        """Drop a swap record and return its host ids to the tier — the
        restore-complete path, and the WHOLE release path for a request
        cancelled while preempted: its device references were already
        dropped once at swap_out, so freeing host capacity must not touch
        device refcounts again (the double-unref edge,
        tests/test_scheduler.py)."""
        rec = self.swapped.pop(key)
        self._host_free.extend(rec.host_ids)
        return rec

    def observe(self, metrics=None) -> None:
        """Publish pool occupancy gauges into a MetricsRegistry (the one
        given, else the pool's own).  Pure read — safe at any point the
        pool is consistent (serve calls it once per tick)."""
        m = metrics if metrics is not None else self.metrics
        if m is None:
            return
        m.gauge("pool/free_blocks").set(self.num_free)
        m.gauge("pool/used_blocks").set(
            self.layout.num_blocks - 1 - self.num_free)
        m.gauge("pool/active_slots").set(int(self.active.sum()))
        m.gauge("pool/shared_blocks").set(int((self.ref > 1).sum()))
        m.gauge("pool/host_free_blocks").set(self.host_free)
        m.gauge("pool/swapped_seqs").set(len(self.swapped))

    def check_conservation(self) -> None:
        """Refcount conservation (DESIGN.md §10): refcounts never negative,
        the null block is never referenced or freed, a non-null block is on
        the free list iff its refcount is zero, free + referenced blocks
        partition the pool, and every active slot's chain is fully live.
        Raises AssertionError on any violation (the hypothesis property
        test drives random op interleavings through this)."""
        assert (self.ref >= 0).all()
        assert int(self.ref[NULL_BLOCK]) == 0
        free = list(self._free)
        assert len(set(free)) == len(free), "duplicate ids on the free list"
        assert NULL_BLOCK not in free
        for bid in free:
            assert self.ref[bid] == 0, f"freed block {bid} still referenced"
        n_live = int((self.ref[1:] > 0).sum())
        assert len(free) + n_live == self.layout.num_blocks - 1
        for b in range(self.batch_slots):
            if self.active[b]:
                assert all(self.ref[bid] >= 1 for bid in self._chain[b])
                assert self._nshared[b] <= len(self._chain[b])
            else:
                assert not self._chain[b]
                assert (self.table[b] == NULL_BLOCK).all()
        # host tier: free ids + swap-record ids partition [0, host_blocks),
        # and no record claims more rows than its host blocks can hold
        hf = list(self._host_free)
        assert len(set(hf)) == len(hf), "duplicate ids on the host free list"
        used = [h for r in self.swapped.values() for h in r.host_ids]
        assert len(set(used)) == len(used), "host block in two swap records"
        assert not set(hf) & set(used)
        assert len(hf) + len(used) == self.host_blocks
        for r in self.swapped.values():
            nb = self.layout.blocks_for(r.n_tokens) if r.n_tokens else 0
            assert len(r.host_ids) == nb and r.n_tokens <= r.budget

    def audit(self) -> None:
        """The paranoia sweep (DESIGN.md §12, ``--paranoia N``):
        :meth:`check_conservation` plus the FULL-ROW null audit over every
        slot — table columns beyond each chain must be null and the mapped
        columns must mirror the chain exactly, written lengths must fit
        budgets, and budgets must fit chains — so invariant corruption
        surfaces at the scheduler step that caused it, not at release
        time.  Raises AssertionError on any violation."""
        self.check_conservation()
        for b in range(self.batch_slots):
            chain = self._chain[b]
            assert (self.table[b, len(chain):] == NULL_BLOCK).all(), \
                f"slot {b}: stale ids beyond its chain"
            assert (self.table[b, :len(chain)]
                    == np.asarray(chain, np.int32)).all(), \
                f"slot {b}: table row disagrees with its chain"
            assert int(self.lengths[b]) <= int(self._budget[b])
            if self._budget[b]:
                assert self.layout.blocks_for(int(self._budget[b])) \
                    <= len(chain), f"slot {b}: budget outruns its chain"

    def device_views(self):
        """(block_table [B, max_blocks], lengths [B]) as device arrays.

        COPIES, not views: jnp.array, never jnp.asarray.  On CPU jaxlib
        zero-copies aligned numpy buffers into device arrays, and JAX
        dispatch is async — an in-flight decode step would read the
        allocator's live table/lengths AFTER a subsequent host-side
        append()/release() mutated them (shifting the token write slot),
        a race that corrupts cache rows nondeterministically."""
        return jnp.array(self.table), jnp.array(self.lengths)


# --------------------------------------------------------- device-side ops
def append_rows(pool, table, lengths, rows):
    """Write one new token row per sequence at its current length.

    pool: [N, bs, *F]; table: [B, max_blocks] int32; lengths: [B] int32
    (write position = lengths[b]); rows: [B, *F].  Inactive slots (all-null
    table, length 0) land in the null block — harmless, masked on read."""
    bs = pool.shape[1]
    blk = lengths // bs
    slot = lengths % bs
    pid = jnp.take_along_axis(table, blk[:, None], axis=1)[:, 0]   # [B]
    return pool.at[pid, slot].set(rows)


def append_chunk(pool, table, lengths, rows):
    """Write a C-token chunk per sequence starting at its current length.

    pool: [N, bs, *F]; table: [B, max_blocks] int32; lengths: [B] int32
    (chunk token c of sequence b lands at logical position lengths[b] + c);
    rows: [B, C, *F].  The chunked-prefill analogue of :func:`append_rows`:
    one scatter covers the whole chunk even when it straddles block
    boundaries.  Rows of a sequence whose table is all-null (inactive slot)
    land in the null block — harmless, masked on read."""
    bs = pool.shape[1]
    C = rows.shape[1]
    pos = lengths[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # [B,C]
    blk = pos // bs
    slot = pos % bs
    pid = jnp.take_along_axis(table, blk, axis=1)                     # [B,C]
    return pool.at[pid, slot].set(rows.astype(pool.dtype))


def copy_block(pool, src: int, dst: int):
    """Copy-on-write device copy: duplicate physical block `src` into `dst`
    in one pool [N, bs, *F].  The scheduler calls this (via
    models.model.copy_paged_block over the whole cache pytree) on the pair
    returned by :meth:`BlockPool.admit_shared` when a cached prefix ends
    mid-block, before any chunk is appended to the new slot."""
    return pool.at[dst].set(pool[src])


# ------------------------------------------------------------ quantization
def quant_dtype(kv_dtype: str):
    """Pool storage dtype for a KV layout ("fp" -> None: caller keeps the
    config dtype).  Raises on "fp8" when the jax build has no e4m3 type."""
    if kv_dtype == "fp":
        return None
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        if not HAS_FP8:
            raise ValueError(
                "kv_dtype='fp8' needs jnp.float8_e4m3fn (jax >= 0.4.x with "
                "ml_dtypes); use 'int8' or 'fp' on this build")
        return jnp.float8_e4m3fn
    raise ValueError(f"kv_dtype must be one of {KV_LAYOUTS}, got {kv_dtype!r}")


def kv_dtype_of(pool) -> str:
    """Inverse of :func:`quant_dtype`: classify a pool by its dtype."""
    if pool.dtype == jnp.int8:
        return "int8"
    if HAS_FP8 and pool.dtype == jnp.float8_e4m3fn:
        return "fp8"
    return "fp"


def quantize_rows(rows, kv_dtype: str):
    """Quantize fp rows to (codes, sz) with one affine pair per row.

    rows: [..., F] — the last axis is the feature vector quantized as one
    granule (per kv-head granularity falls out of the leading axes: a GQA
    row [B, K, hd] carries K independent pairs).  Returns
    (codes [..., F] in :func:`quant_dtype`, sz [..., 2] fp32) with
    ``sz[..., 0]`` the scale and ``sz[..., 1]`` the zero-point, such that
    ``dequantize_rows(codes, sz) ≈ rows``:

        int8:  zp = (max+min)/2, scale = (max-min)/254,
               codes = round((x - zp)/scale) ∈ [-127, 127]
        fp8:   zp = 0, scale = amax/448, codes = e4m3(x/scale)

    Degenerate rows (max == min, e.g. the all-zero rows of a fresh pool)
    take scale = 1 so the affine stays invertible and the row round-trips
    exactly (codes 0, zp = the constant).  Quantization is a pure function
    of the row values — re-quantizing identical rows is bitwise stable,
    which is what makes prefix-cached decode bitwise equal to uncached
    *within* a kv layout."""
    dt = quant_dtype(kv_dtype)
    if dt is None:
        raise ValueError("quantize_rows on a 'fp' layout — nothing to do")
    x = rows.astype(jnp.float32)
    hi = jnp.max(x, axis=-1, keepdims=True)
    lo = jnp.min(x, axis=-1, keepdims=True)
    if kv_dtype == "int8":
        zp = (hi + lo) * 0.5
        rng = hi - lo
        scale = jnp.where(rng > 0, rng / (2.0 * INT8_MAX), 1.0)
        codes = jnp.round((x - zp) / scale)
        codes = jnp.clip(codes, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    else:                                     # fp8: symmetric, sign in-code
        amax = jnp.maximum(jnp.abs(hi), jnp.abs(lo))
        zp = jnp.zeros_like(hi)
        scale = jnp.where(amax > 0, amax / F8_MAX, 1.0)
        # clamp BEFORE the cast: e4m3fn has no inf, overflow would be nan
        codes = jnp.clip(x / scale, -F8_MAX, F8_MAX).astype(dt)
    sz = jnp.concatenate([scale, zp], axis=-1)          # [..., 2]
    return codes, sz


def dequantize_rows(codes, sz):
    """Inverse of :func:`quantize_rows`: ``codes*scale + zp`` in fp32.
    codes: [..., F]; sz: [..., 2].  This is the SAME expression the Pallas
    kernels apply in registers (kernels/etap/etap.py:_dequant) — the XLA
    reference twin (kernels/etap/ref.py) goes through here, so kernel and
    oracle share one definition of the dequant."""
    return (codes.astype(jnp.float32) * sz[..., 0:1] + sz[..., 1:2])


def quantize_pool(pool, kv_dtype: str):
    """Quantize a whole fp pool [N, bs, *F] into (codes, sz [N, bs, *lead, 2])
    — the test/bench path that packs a prefilled fp pool (dense_to_paged)
    into the quantized layout wholesale."""
    return quantize_rows(pool, kv_dtype)


def row_bytes(feat: int, kv_dtype: str, fp_dtype=jnp.bfloat16,
              granules: int = 1) -> int:
    """KV bytes per written token row: `feat` features stored in the
    layout's code dtype plus (for quantized layouts) `granules` fp32
    (scale, zp) pairs.  The capacity lever the serve loop admits by."""
    if kv_dtype == "fp":
        return feat * jnp.dtype(fp_dtype).itemsize
    return feat + granules * 8            # 1-byte codes + fp32 (scale, zp)


def layout_for_bytes(budget_bytes: int, bytes_per_row: int, max_len: int,
                     block_size: int = 64, spare_blocks: int = 0):
    """Size a (layout, batch_slots) pair to a pool BYTE budget: as many
    blocks as the budget buys at `bytes_per_row`, and as many full-length
    batch slots as those blocks can back.  With the fp row size this
    reproduces :func:`layout_for` exactly; with a quantized row size the
    same budget admits ~2x (int8) the sequences — the acceptance lever of
    DESIGN.md §11.  `spare_blocks` are held OUT of the slot computation
    (the operator's COW / mid-block-admission headroom survives the
    quantized re-sizing instead of being folded into extra slots)."""
    max_blocks = max(1, -(-int(max_len) // block_size))
    block_bytes = block_size * int(bytes_per_row)
    num_blocks = max(2, 1 + int(budget_bytes) // block_bytes)
    usable = max(1, num_blocks - 1 - max(0, int(spare_blocks)))
    batch_slots = max(1, usable // max_blocks)
    return (PagedLayout(block_size=block_size, num_blocks=num_blocks,
                        max_blocks=max_blocks), batch_slots)


def append_rows_quant(pool, sz_pool, table, lengths, rows):
    """Quantized :func:`append_rows`: quantize the new rows in the pool's
    layout and scatter codes + (scale, zp) through the same table/length
    coordinates.  rows arrive in fp; returns (pool, sz_pool)."""
    codes, sz = quantize_rows(rows, kv_dtype_of(pool))
    return (append_rows(pool, table, lengths, codes),
            append_rows(sz_pool, table, lengths, sz))


def append_chunk_quant(pool, sz_pool, table, lengths, rows):
    """Quantized :func:`append_chunk` (rows: [B, C, *F])."""
    codes, sz = quantize_rows(rows, kv_dtype_of(pool))
    return (append_chunk(pool, table, lengths, codes),
            append_chunk(sz_pool, table, lengths, sz))


def gather_blocks(pool, table):
    """Dense [B, max_blocks * bs, *F] view of the paged rows (the XLA
    fallback / oracle path — the Pallas kernels never materialize this;
    they index the pool through the table inside the grid)."""
    B, nb = table.shape
    bs = pool.shape[1]
    g = pool[table]                                   # [B, nb, bs, *F]
    return g.reshape(B, nb * bs, *pool.shape[2:])


def dense_to_paged(dense, lengths, layout: PagedLayout):
    """Pack a dense [B, S, *F] cache into (pool, BlockPool) — test/bench
    helper and the dense→paged migration path.  Allocation order follows
    slot order, so tables are NOT identity maps of logical order across
    sequences (which is exactly what the kernels must be robust to)."""
    B, S = dense.shape[:2]
    pool_host = np.zeros((layout.num_blocks, layout.block_size)
                         + dense.shape[2:], np.asarray(dense).dtype)
    bp = BlockPool(layout, B)
    dense_np = np.asarray(dense)
    for b in range(B):
        n = int(lengths[b])
        slot = bp.admit(n, n)
        assert slot == b, "fresh pool admits in slot order"
        ids = bp.block_ids(b)
        nb = len(ids)
        padded = np.zeros((nb * layout.block_size,) + dense.shape[2:],
                          dense_np.dtype)
        padded[:n] = dense_np[b, :n]
        pool_host[ids] = padded.reshape(nb, layout.block_size,
                                        *dense.shape[2:])
    return jnp.asarray(pool_host), bp


def tree_append_rows(cache, table, lengths, rows):
    """:func:`append_rows` over matching (pool, rows) pytrees whose leaves
    carry a leading stacked-layer axis [n, ...] (the model's grouped cache)."""
    return jax.tree.map(
        lambda p, r: jax.vmap(
            lambda pp, rr: append_rows(pp, table, lengths, rr))(p, r),
        cache, rows)
