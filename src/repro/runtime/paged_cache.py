"""Paged KV-cache subsystem: block-pool allocator + block-table array ops.

The serving cache is a *pool* of fixed-size KV blocks (pages) shared by all
sequences, FlashMLA/vLLM-style, instead of a dense ``[B, max_len]`` slab:

    pool        [num_blocks, block_size, *feat]   (per layer; jnp, on device)
    block_table [B, max_blocks]  int32            (shared across layers)
    lengths     [B]              int32            (tokens written per slot)

Sequence ``b``'s token at logical position ``t`` lives at
``pool[block_table[b, t // block_size], t % block_size]``.  Block ids are
granted by a host-side free-list (:class:`BlockPool`); the block *table* is
what the paged Pallas kernels prefetch to gather KV through (see
``kernels/etap/etap.py``).

Allocator invariants (DESIGN.md §8):
  · Block 0 is the reserved NULL block: never allocated, every padded /
    released table entry points at it.  Inactive batch slots therefore
    write their (ignored) decode rows into block 0 and read back finite
    garbage that is masked by ``length`` — no branches anywhere on device.
  · Admission reserves blocks for the request's full budget
    (prompt + max new tokens) up front, so a decode step can never fail
    mid-flight; running out of blocks is an *admission refusal*, which the
    continuous-batching scheduler (launch/serve.py) handles by queueing.
  · ``release`` returns blocks to the free list and zeroes the table row,
    so ids are recycled across requests (tests/test_paged.py proves
    reuse-after-release and the refusal path).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NULL_BLOCK = 0


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of a paged cache."""
    block_size: int            # tokens per KV block (page)
    num_blocks: int            # pool size, INCLUDING the reserved null block
    max_blocks: int            # block-table width (max logical blocks/seq)

    def __post_init__(self):
        assert self.block_size >= 1 and self.max_blocks >= 1
        assert self.num_blocks >= 2, "need at least null block + one real block"

    @property
    def max_len(self) -> int:
        return self.max_blocks * self.block_size

    def blocks_for(self, n_tokens: int) -> int:
        return max(1, -(-int(n_tokens) // self.block_size))


def layout_for(batch_slots: int, max_len: int, block_size: int = 64,
               spare_blocks: int = 0) -> PagedLayout:
    """A layout that can hold `batch_slots` full-length sequences (+spares)."""
    max_blocks = max(1, -(-int(max_len) // block_size))
    return PagedLayout(block_size=block_size,
                       num_blocks=1 + batch_slots * max_blocks + spare_blocks,
                       max_blocks=max_blocks)


class BlockPool:
    """Host-side free-list allocator over `layout.num_blocks` KV blocks,
    owning the block table and per-slot lengths for `batch_slots` slots."""

    def __init__(self, layout: PagedLayout, batch_slots: int):
        self.layout = layout
        self.batch_slots = batch_slots
        # pop order low→high keeps tables human-readable in tests/logs
        self._free = deque(range(1, layout.num_blocks))      # 0 = null block
        self.table = np.zeros((batch_slots, layout.max_blocks), np.int32)
        self.lengths = np.zeros((batch_slots,), np.int32)
        self.active = np.zeros((batch_slots,), bool)
        self._owned: list[list[int]] = [[] for _ in range(batch_slots)]
        self._budget = np.zeros((batch_slots,), np.int32)    # reserved tokens

    @property
    def num_free(self) -> int:
        return len(self._free)

    def free_slots(self) -> list[int]:
        return [b for b in range(self.batch_slots) if not self.active[b]]

    def can_admit(self, max_total_len: int) -> bool:
        """Admission predicate: a free batch slot AND enough free blocks to
        reserve the request's whole token budget."""
        if max_total_len > self.layout.max_len:
            return False
        need = self.layout.blocks_for(max_total_len)
        return bool(self.free_slots()) and need <= self.num_free

    def admit(self, prompt_len: int, max_total_len: int) -> Optional[int]:
        """Reserve a slot + blocks for `max_total_len` tokens; returns the
        slot id, or None (admission refusal — the caller keeps the request
        queued).  `prompt_len` rows are accounted as already written (the
        test/bench path that packs a prefilled dense cache via
        :func:`dense_to_paged`).  prompt_len 0 is a COLD admission: blocks
        are reserved but nothing is written yet — the chunked-prefill
        scheduler grows the length via :func:`extend` as it appends prompt
        chunks (launch/serve.py, DESIGN.md §9)."""
        assert 0 <= prompt_len <= max_total_len and max_total_len >= 1
        if not self.can_admit(max_total_len):
            return None
        slot = self.free_slots()[0]
        need = self.layout.blocks_for(max_total_len)
        ids = [self._free.popleft() for _ in range(need)]
        self._owned[slot] = ids
        self.table[slot] = NULL_BLOCK
        self.table[slot, :need] = ids
        self.lengths[slot] = prompt_len
        self._budget[slot] = max_total_len
        self.active[slot] = True
        return slot

    def block_ids(self, slot: int) -> np.ndarray:
        """Physical block ids owned by `slot` (allocation order = logical)."""
        return np.asarray(self._owned[slot], np.int32)

    def append(self, slot: int) -> None:
        """Account one generated token for `slot` (the device-side write is
        :func:`append_rows`).  Never allocates: admission already reserved
        the full budget."""
        assert self.active[slot]
        assert self.lengths[slot] < self._budget[slot], \
            f"slot {slot} exceeded its reserved budget"
        self.lengths[slot] += 1

    def extend(self, slot: int, n: int) -> None:
        """Account `n` prompt tokens appended to `slot` in one prefill chunk
        (the device-side write is :func:`append_chunk`).  Never allocates:
        admission already reserved the full budget, so a chunk can never run
        out of blocks mid-prompt."""
        assert self.active[slot] and n >= 0
        assert self.lengths[slot] + n <= self._budget[slot], \
            f"slot {slot} chunk of {n} exceeds its reserved budget"
        self.lengths[slot] += n

    def release(self, slot: int) -> None:
        """Return `slot`'s blocks to the free list and null its table row."""
        assert self.active[slot]
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self.table[slot] = NULL_BLOCK
        self.lengths[slot] = 0
        self._budget[slot] = 0
        self.active[slot] = False

    def device_views(self):
        """(block_table [B, max_blocks], lengths [B]) as device arrays.

        COPIES, not views: jnp.array, never jnp.asarray.  On CPU jaxlib
        zero-copies aligned numpy buffers into device arrays, and JAX
        dispatch is async — an in-flight decode step would read the
        allocator's live table/lengths AFTER a subsequent host-side
        append()/release() mutated them (shifting the token write slot),
        a race that corrupts cache rows nondeterministically."""
        return jnp.array(self.table), jnp.array(self.lengths)


# --------------------------------------------------------- device-side ops
def append_rows(pool, table, lengths, rows):
    """Write one new token row per sequence at its current length.

    pool: [N, bs, *F]; table: [B, max_blocks] int32; lengths: [B] int32
    (write position = lengths[b]); rows: [B, *F].  Inactive slots (all-null
    table, length 0) land in the null block — harmless, masked on read."""
    bs = pool.shape[1]
    blk = lengths // bs
    slot = lengths % bs
    pid = jnp.take_along_axis(table, blk[:, None], axis=1)[:, 0]   # [B]
    return pool.at[pid, slot].set(rows)


def append_chunk(pool, table, lengths, rows):
    """Write a C-token chunk per sequence starting at its current length.

    pool: [N, bs, *F]; table: [B, max_blocks] int32; lengths: [B] int32
    (chunk token c of sequence b lands at logical position lengths[b] + c);
    rows: [B, C, *F].  The chunked-prefill analogue of :func:`append_rows`:
    one scatter covers the whole chunk even when it straddles block
    boundaries.  Rows of a sequence whose table is all-null (inactive slot)
    land in the null block — harmless, masked on read."""
    bs = pool.shape[1]
    C = rows.shape[1]
    pos = lengths[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # [B,C]
    blk = pos // bs
    slot = pos % bs
    pid = jnp.take_along_axis(table, blk, axis=1)                     # [B,C]
    return pool.at[pid, slot].set(rows.astype(pool.dtype))


def gather_blocks(pool, table):
    """Dense [B, max_blocks * bs, *F] view of the paged rows (the XLA
    fallback / oracle path — the Pallas kernels never materialize this;
    they index the pool through the table inside the grid)."""
    B, nb = table.shape
    bs = pool.shape[1]
    g = pool[table]                                   # [B, nb, bs, *F]
    return g.reshape(B, nb * bs, *pool.shape[2:])


def dense_to_paged(dense, lengths, layout: PagedLayout):
    """Pack a dense [B, S, *F] cache into (pool, BlockPool) — test/bench
    helper and the dense→paged migration path.  Allocation order follows
    slot order, so tables are NOT identity maps of logical order across
    sequences (which is exactly what the kernels must be robust to)."""
    B, S = dense.shape[:2]
    pool_host = np.zeros((layout.num_blocks, layout.block_size)
                         + dense.shape[2:], np.asarray(dense).dtype)
    bp = BlockPool(layout, B)
    dense_np = np.asarray(dense)
    for b in range(B):
        n = int(lengths[b])
        slot = bp.admit(n, n)
        assert slot == b, "fresh pool admits in slot order"
        ids = bp.block_ids(b)
        nb = len(ids)
        padded = np.zeros((nb * layout.block_size,) + dense.shape[2:],
                          dense_np.dtype)
        padded[:n] = dense_np[b, :n]
        pool_host[ids] = padded.reshape(nb, layout.block_size,
                                        *dense.shape[2:])
    return jnp.asarray(pool_host), bp


def tree_append_rows(cache, table, lengths, rows):
    """:func:`append_rows` over matching (pool, rows) pytrees whose leaves
    carry a leading stacked-layer axis [n, ...] (the model's grouped cache)."""
    return jax.tree.map(
        lambda p, r: jax.vmap(
            lambda pp, rr: append_rows(pp, table, lengths, rr))(p, r),
        cache, rows)
