"""Fault-tolerance runtime: heartbeats, straggler detection, failure
injection and the restartable training driver pieces.

On a real multi-pod deployment these run per-host against a coordination
service; here the same logic runs in-process (single-host container) and is
exercised by tests/test_runtime.py — the *state machines* are what matters:
  · HeartbeatRegistry: workers check in; silence > timeout => failure
  · StragglerDetector: per-host step-time z-score (robust MAD) => slow host
  · FailureInjector  : deterministic fault schedule for drills
  · plan_remesh      : failed hosts => next viable (data, model) mesh shape

Each state machine takes an optional ``metrics`` MetricsRegistry
(runtime/telemetry.py) and emits ``ft/*`` counters — heartbeats, straggler
flags, injected faults — so ``--fault-rate`` drills show up in the serve
metrics snapshot.  The StragglerDetector wiring is the DESIGN.md §15
hand-off point for multi-device serving (ROADMAP item 1): per-worker step
gauges are already published here; only the per-device record() calls are
missing.  Telemetry never changes any decision these classes make.
"""
from __future__ import annotations

import time
from collections.abc import Sequence
from dataclasses import dataclass, field

import numpy as np


class HeartbeatRegistry:
    def __init__(self, timeout_s: float = 30.0, clock=time.monotonic, *,
                 metrics=None):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: dict[str, float] = {}
        self.metrics = metrics

    def beat(self, worker: str):
        self._last[worker] = self._clock()
        if self.metrics is not None:
            self.metrics.inc("ft/heartbeats")

    def alive(self) -> list[str]:
        now = self._clock()
        return [w for w, t in self._last.items() if now - t <= self.timeout_s]

    def dead(self) -> list[str]:
        now = self._clock()
        return [w for w, t in self._last.items() if now - t > self.timeout_s]


class StragglerDetector:
    """Flags hosts whose step time exceeds median + z·MAD over a window."""

    def __init__(self, window: int = 16, z: float = 4.0, *, metrics=None):
        self.window = window
        self.z = z
        self._times: dict[str, list[float]] = {}
        self.metrics = metrics

    def record(self, worker: str, step_time_s: float):
        buf = self._times.setdefault(worker, [])
        buf.append(step_time_s)
        if len(buf) > self.window:
            buf.pop(0)
        if self.metrics is not None:
            self.metrics.inc("ft/step_samples")
            self.metrics.observe(f"ft/step_ms/{worker}",
                                 step_time_s * 1e3)

    def stragglers(self) -> list[str]:
        if len(self._times) < 2:
            return []
        med_per = {w: float(np.median(t)) for w, t in self._times.items()
                   if len(t) >= 4}
        if len(med_per) < 2:
            return []
        meds = np.array(list(med_per.values()))
        med = float(np.median(meds))
        mad = float(np.median(np.abs(meds - med))) + 1e-9
        out = [w for w, m in med_per.items()
               if (m - med) / (1.4826 * mad) > self.z]
        if self.metrics is not None and out:
            self.metrics.inc("ft/straggler_flags", len(out))
        return out


@dataclass
class FailureInjector:
    """Deterministic fault schedule: raise WorkerFailure at given steps."""
    fail_at_steps: Sequence[int] = field(default_factory=tuple)
    metrics: object = None

    def check(self, step: int):
        if step in self.fail_at_steps:
            if self.metrics is not None:
                self.metrics.inc("ft/injected_faults")
            raise WorkerFailure(f"injected failure at step {step}")

    @classmethod
    def from_rate(cls, rate: float, horizon: int = 100_000, *,
                  metrics=None):
        """Schedule matching a mean failure RATE (failures per step): one
        failure every round(1/rate) steps out to `horizon`.  Periodic, not
        sampled — the serve loop's --fault-rate drills must be replayable
        bit-for-bit, and a deterministic schedule is what lets the test
        assert the faulted run's outputs against the unfaulted run's."""
        assert 0 < rate <= 1, f"rate must be in (0, 1], got {rate}"
        period = max(1, round(1.0 / rate))
        return cls(fail_at_steps=frozenset(range(period, horizon, period)),
                   metrics=metrics)


class WorkerFailure(RuntimeError):
    pass


def plan_remesh(n_alive_hosts: int, chips_per_host: int,
                model_parallel: int) -> tuple | None:
    """Largest (data, model) mesh that fits the surviving chips with the
    required model-parallel degree; None if impossible. Elastic scale-down
    keeps TP intact and shrinks the data axis (checkpoint reshard-on-load
    handles the rest — see checkpoint.restore)."""
    chips = n_alive_hosts * chips_per_host
    if chips < model_parallel:
        return None
    data = chips // model_parallel
    # power-of-two data axis keeps batch divisibility predictable
    data = 1 << (data.bit_length() - 1)
    return (data, model_parallel)
