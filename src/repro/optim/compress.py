"""Gradient compression for cross-pod (DCN-class) reductions.

int8 per-chunk-scaled quantization with error feedback:
    q = round(g / s),  s = max|g_chunk| / 127        (per 256-elem chunk)
    residual r += g - dequant(q)   carried to the next step (error feedback)
The quantized payload crosses the slow `pod` axis; scales are f32 but tiny
(1/256 of elements). Inside a pod, gradients reduce at full precision.

Two integration points:
  · `compressed_psum(x, axis)` — shard_map-level collective (tested directly)
  · `PodReducer` — pytree-level wrapper with persistent error-feedback state
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 256


def _pad_to_chunks(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % CHUNK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, CHUNK), pad


def quantize(g) -> tuple[jax.Array, jax.Array]:
    """g: any-shape f32/bf16 -> (int8 chunks [n,CHUNK], scales f32 [n])."""
    chunks, _ = _pad_to_chunks(g.astype(jnp.float32))
    scale = jnp.max(jnp.abs(chunks), axis=1) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(chunks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_decompress(g):
    """Round-trip (the compression that the wire would carry)."""
    q, s = quantize(g)
    return dequantize(q, s, g.shape)


def compressed_psum(x, axis: str):
    """all-reduce over `axis` carrying int8 payloads + f32 scales.
    Mathematically: sum over shards of dequant(quant(x_i)). Must be called
    inside shard_map with `axis` manual."""
    q, s = quantize(x)
    # each shard contributes dequant(q)·1; reduce the *dequantized* values —
    # wire format is (int8 q, f32 s); on TPU the DCN transfer is the int8
    # payload, the psum here models the arithmetic.
    contrib = dequantize(q, s, x.shape)
    return jax.lax.psum(contrib, axis)


def pod_reduce_with_feedback(grads, residual, axis: str = "pod"):
    """One error-feedback compression step for a gradient pytree that is
    about to cross the pod axis. Returns (reduced_grads, new_residual).
    Call inside shard_map over `axis` (or without a mesh: identity+feedback)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize(g32)
        deq = dequantize(q, s, g32.shape)
        new_r = g32 - deq
        from repro import compat
        mesh = compat.get_mesh()
        if mesh is not None and axis in getattr(mesh, "axis_names", ()):
            try:
                deq = jax.lax.psum(deq, axis) / mesh.shape[axis]
            except NameError:
                pass   # not inside shard_map: local-only (tests)
        return deq.astype(g.dtype), new_r
    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r, strict=True)]
    return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])


def init_residual(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_tree_for_pod_reduce(grads):
    """Stateless variant used by the dry-run train step when
    TrainConfig.compress_grads is on: models the quantize→reduce→dequantize
    arithmetic (error feedback lives in the trainer loop state)."""
    return jax.tree.map(compress_decompress, grads)
