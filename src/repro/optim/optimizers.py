"""Optimizers: AdamW (configurable moment dtypes for HBM-constrained FSDP)
and Adafactor (factored second moment — the 400B/671B train cells), plus
global-norm clipping and a linear-warmup cosine schedule. Pure pytree
functions; optimizer state shards exactly like params (moments inherit the
param PartitionSpec; adafactor row/col stats inherit the reduced specs).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "bfloat16"      # first-moment storage (adamw)
    v_dtype: str = "bfloat16"      # second-moment storage (adamw)
    # adafactor
    min_dim_size_to_factor: int = 128


def schedule(cfg: OptimizerConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# -------------------------------------------------------------------- AdamW
def adamw_init(cfg: OptimizerConfig, params):
    mdt, vdt = jnp.dtype(cfg.m_dtype), jnp.dtype(cfg.v_dtype)
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, vdt), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    c = state["count"] + 1
    lr = schedule(cfg, c)
    b1c = 1.0 - cfg.b1 ** c.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** c.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        step = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "count": c}


# ---------------------------------------------------------------- Adafactor
def _factored(shape, cfg) -> bool:
    return len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor \
        and shape[-2] >= cfg.min_dim_size_to_factor


def adafactor_init(cfg: OptimizerConfig, params):
    def one(p):
        if _factored(p.shape, cfg):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(one, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, grads, state, params):
    c = state["count"] + 1
    lr = schedule(cfg, c)
    beta2 = 1.0 - c.astype(jnp.float32) ** -0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + 1e-30
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None] * vc[..., None, :]
            step = g * jax.lax.rsqrt(denom + 1e-30)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
            step = g * jax.lax.rsqrt(nv["v"] + 1e-30)
        # update clipping (Adafactor d=1.0)
        rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-30)
        step = step / jnp.maximum(1.0, rms)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p, strict=True)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_v = treedef.unflatten([o[1] for o in outs])
    return new_p, {"v": new_v, "count": c}


# ------------------------------------------------------------------ facade
def opt_init(cfg: OptimizerConfig, params):
    return adafactor_init(cfg, params) if cfg.name == "adafactor" \
        else adamw_init(cfg, params)


def opt_update(cfg: OptimizerConfig, grads, state, params):
    if cfg.name == "adafactor":
        return adafactor_update(cfg, grads, state, params)
    return adamw_update(cfg, grads, state, params)
