"""Sharded checkpointing with async save, atomic commit, and elastic
reshard-on-load.

Layout:  <dir>/step_<N>/
            arrays/<flat-key>.npy     one file per pytree leaf
            MANIFEST.json             tree structure + shapes/dtypes + step
The manifest is written LAST — its presence is the commit point, so a crash
mid-save can never yield a checkpoint that restore() would accept
(fault-tolerance invariant tested in tests/test_runtime.py).

restore(..., mesh=...) re-shards every leaf onto the target mesh via
jax.device_put — a checkpoint taken on (16,16) restores onto (8,16) or
(2,16,16) (elastic scale-down / scale-up).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_SEP = "__"

# numpy can't serialize bf16/fp8 natively: store bit patterns + logical dtype
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(v: np.ndarray):
    if str(v.dtype) in _BITCAST:
        return v.view(_BITCAST[str(v.dtype)]), str(v.dtype)
    return v, str(v.dtype)


def _from_storable(v: np.ndarray, dtype: str):
    if dtype in _BITCAST:
        return v.view(getattr(ml_dtypes, dtype))
    return v


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _SEP.join(_part(k) for k in kp)
        out[key] = leaf
    return out


def _part(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def _unflatten_into(template, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        key = _SEP.join(_part(k) for k in kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(arrays[key])
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True):
    """Write checkpoint for `step`. With blocking=False the copy runs on a
    background thread (async checkpointing); call .join() on the returned
    thread before exiting."""
    flat = _flatten(tree)
    # pull to host BEFORE the thread (device buffers may be donated later)
    host = {k: np.asarray(v) for k, v in flat.items()}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step}")
        tmp = final + ".tmp"
        arr_dir = os.path.join(tmp, "arrays")
        os.makedirs(arr_dir, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for k, v in host.items():
            stored, dtype = _to_storable(v)
            np.save(os.path.join(arr_dir, k + ".npy"), stored)
            manifest["leaves"][k] = {"shape": list(v.shape), "dtype": dtype}
        os.replace(tmp, final)   # atomic rename …
        with open(os.path.join(final, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)   # … manifest last = commit point

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=False)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *committed* checkpoint (manifest present)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "MANIFEST.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, *, mesh=None, shardings=None):
    """Load checkpoint into the structure of `template`. If `shardings`
    (pytree of NamedSharding matching template) is given, leaves are placed
    sharded — onto a *different* mesh than the one that saved them if needed
    (elastic reshard)."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(final, "MANIFEST.json")) as f:
        manifest = json.load(f)
    arrays = {}
    for k, meta in manifest["leaves"].items():
        v = np.load(os.path.join(final, "arrays", k + ".npy"))
        arrays[k] = _from_storable(v, meta["dtype"])
    tree = _unflatten_into(template, arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s, t: jax.device_put(jnp.asarray(a, t.dtype), s),
            tree, shardings, template)
    else:
        tree = jax.tree.map(lambda a, t: jnp.asarray(a, t.dtype), tree, template)
    return tree, manifest["step"]


def gc_old(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest `keep` committed checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(m.group(1)) for m in (re.fullmatch(r"step_(\d+)", n)
                                  for n in os.listdir(ckpt_dir)) if m))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
