"""Config system: model/architecture configs + input-shape cells.

Every assigned architecture gets a module in this package exposing ``CONFIG``.
``get_config(name)`` resolves by arch id; ``reduced(cfg)`` shrinks any config to
a CPU-smoke-testable size of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from collections.abc import Sequence
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    @property
    def latent_dim(self) -> int:
        # absorbed decode operates on [kv_lora_rank + rope] = 576 for DeepSeek.
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0          # expert hidden size (0 -> use model d_ff)
    shared_expert: bool = False   # llama4/deepseek shared expert
    capacity_factor: float = 1.25
    first_dense_layers: int = 0   # deepseek: first k layers are dense
    every_k_layers: int = 1       # 1 = every layer is MoE


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)
    chunk: int = 256              # selective-scan chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm | mla
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    attention_kind: str = "full"  # full | local | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window_size: int = 2048       # local attention window
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid layer pattern, cycled over num_layers. e.g. ("rglru","rglru","attn")
    block_pattern: Sequence[str] | None = None
    frontend: str | None = None       # "audio" | "vision" stub frontends
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # runtime switches
    use_kernels: bool = False     # Pallas path (tests/bench); XLA path for dry-run
    remat: bool = True
    # RG-LRU width (recurrentgemma); 0 -> d_model
    lru_width: int = 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode over 500K context is feasible (SSM / local-attn hybrid)."""
        return self.attention_kind in ("none", "local") or self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> list:
        """Per-layer temporal-mixing kind."""
        if self.block_pattern:
            pat = list(self.block_pattern)
            return [pat[i % len(pat)] for i in range(self.num_layers)]
        if self.family == "ssm":
            return ["ssm"] * self.num_layers
        return ["attn"] * self.num_layers

    def moe_layer(self, i: int) -> bool:
        m = self.moe
        if m is None:
            return False
        if i < m.first_dense_layers:
            return False
        return ((i - m.first_dense_layers) % m.every_k_layers) == 0


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "recurrentgemma_9b",
    "dbrx_132b",
    "llama4_maverick_400b",
    "qwen3_8b",
    "stablelm_1_6b",
    "granite_20b",
    "smollm_360m",
    "musicgen_large",
    "llava_next_34b",
    "falcon_mamba_7b",
    "deepseek_r1_671b",   # the paper's own architecture
]


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def cells_for(cfg: ModelConfig) -> list:
    """Shape cells that are runnable for this architecture (skips documented
    in DESIGN.md §Arch-applicability: long_500k needs sub-quadratic decode)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            heads: int = 4, kv_heads: int = 0, d_ff: int = 128,
            vocab: int = 256) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving the family structure."""
    kv = kv_heads or max(1, min(cfg.num_kv_heads, heads))
    changes = dict(
        num_layers=layers, d_model=d_model, num_heads=heads, num_kv_heads=kv,
        d_ff=d_ff, vocab_size=vocab, head_dim=d_model // heads,
        window_size=min(cfg.window_size, 32), remat=False, dtype="float32",
        lru_width=0,
    )
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16)
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=d_ff,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            capacity_factor=8.0)   # effectively dropless for tiny smoke shapes
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=4, chunk=8)
    return dataclasses.replace(cfg, **changes)
