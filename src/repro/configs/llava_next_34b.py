"""llava-next-34b [vlm] — anyres tiling; vision frontend stubbed (precomputed
patch embeddings per the brief). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava_next_34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=20480, vocab_size=64000,
    frontend="vision",
)
