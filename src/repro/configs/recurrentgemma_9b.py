"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    attention_kind="local", window_size=2048,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
)
