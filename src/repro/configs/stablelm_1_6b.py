"""stablelm-1.6b [dense]. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm_1_6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=5632, vocab_size=100352,
)
