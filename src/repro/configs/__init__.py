from repro.configs.base import (ARCH_IDS, SHAPES, MLAConfig, ModelConfig,
                                MoEConfig, ShapeCell, SSMConfig, cells_for,
                                get_config, reduced)
