from repro.configs.base import (ARCH_IDS, SHAPES, MLAConfig, MoEConfig,
                                ModelConfig, SSMConfig, ShapeCell, cells_for,
                                get_config, reduced)
