"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4_maverick_400b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202048,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192, shared_expert=True),
)
