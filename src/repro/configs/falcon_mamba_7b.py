"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free. The paper's ETAP
technique is inapplicable here (no attention GEMM) — see DESIGN.md
§Arch-applicability. [arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon_mamba_7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65024,
    attention_kind="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=4096),
)
