"""musicgen-large [audio] — decoder-only over EnCodec tokens; frontend stubbed
(precomputed frame embeddings per the brief). [arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=2048,
    frontend="audio",
)
