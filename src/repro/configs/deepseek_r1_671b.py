"""deepseek-r1-671b — the paper's own architecture: MLA + MoE 256e top-8.
16 heads/device on a 8-way model split is the exact padding scenario
FlashMLA-ETAP targets. [arXiv:2412.19437]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek_r1_671b", family="mla",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=18432, vocab_size=129280,
    attention_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  shared_expert=True, first_dense_layers=3),
)
