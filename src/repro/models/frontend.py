"""Modality frontends for [audio]/[vlm] backbones. Per the brief these are
STUBS: ``input_specs()`` supplies *precomputed* frame/patch embeddings; the
frontend here is just the projection into the backbone width. Decode operates
in token space (EnCodec codes / text tokens) via the normal embedding table.
"""
from __future__ import annotations

from repro.models import layers

FRONTEND_DIMS = {"audio": 128, "vision": 1024}


def init_frontend(key, cfg, dtype):
    if not cfg.frontend:
        return None
    d_in = FRONTEND_DIMS[cfg.frontend]
    return {"proj": layers.init_dense(key, d_in, cfg.d_model, dtype)}


def apply_frontend(params, embeds):
    """embeds: [B,S,d_frontend] precomputed frames/patches -> [B,S,d_model]."""
    return layers.dense(embeds, params["proj"])
