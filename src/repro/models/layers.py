"""Shared neural layers: norms, rope, MLP, embedding. Pure functions over
param pytrees (dicts); init_* builds params, apply is the function itself."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype) -> jax.Array:
    std = d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def dense(x, w):
    return jnp.einsum("...d,df->...f", x, w)


# ---------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, positions, theta: float):
    """positions: [...]; returns (sin, cos) of shape positions.shape + (head_dim/2,)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim] or [..., seq, head_dim]; positions broadcastable
    to x's seq axis. Rotates pairs (x[..:half], x[..half:]) -- neox style."""
    half = x.shape[-1] // 2
    sin, cos = rope_frequencies(x.shape[-1], positions, theta)
    if x.ndim == sin.ndim + 1:        # [..., seq, heads, dim] vs sin [..., seq, half]
        sin, cos = sin[..., None, :], cos[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP (GLU)
def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d_model, d_ff, dtype),
        "w_up": init_dense(k2, d_model, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d_model, dtype),
    }


def mlp(params, x):
    h = jax.nn.silu(dense(x, params["w_gate"])) * dense(x, params["w_up"])
    return dense(h, params["w_down"])


# ---------------------------------------------------------------- Embedding
def init_embed(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(table, x):
    """Tied unembedding; logits in f32 for a stable softmax/CE."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))
