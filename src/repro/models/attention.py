"""Attention layers: training/prefill (blockwise causal, local-window) and
decode (via the ETAP core).

Sharding notes (DESIGN.md §5): train/prefill attention keeps tensors in the
[B,S,H,*] head-major layout with KV expanded to H heads, so the head dim can
ride the `model` mesh axis whenever divisible (best-effort `constrain`).
Per-chunk jax.checkpoint makes the f32 score blocks transient in the
backward pass (flash-style recompute) instead of stacked residuals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import attn_spec
from repro.core.etap import (decode_attention, gqa_decode_xla, gqa_to_grouped,
                             seq_sharded_gqa_decode)
from repro.models import layers
from repro.runtime import paged_cache
from repro.sharding.rules import BATCH, constrain, seq_shardable

NEG_INF = -1e30


def _score_constraint(s):
    """Scores [B,H,q,S]: shard heads over `model` when divisible, else fall
    back to sharding the q-position dim (e.g. llava's 56 heads on TP16)."""
    mesh = compat.get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return s
    if s.shape[1] % mesh.shape["model"] == 0:
        return constrain(s, P(BATCH, "model", None, None))
    return constrain(s, P(BATCH, None, "model", None))


def _expand_kv(k, H: int):
    """[B,S,K,hd] -> [B,S,H,hd] by group broadcast (keeps head-dim sharding)."""
    B, S, K, hd = k.shape
    G = H // K
    if G == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, K, G, hd))
    return k.reshape(B, S, H, hd)


# ------------------------------------------------------------- train/prefill
def causal_attention(q, k, v, *, scale: float, q_block: int = 512):
    """Blockwise causal attention (chunked over queries; masked full-KV per
    chunk).  q: [B,S,H,D]; k,v: [B,S,K,D*] with H = K*G.  Returns [B,S,H,Dv].

    The XLA path eats the masked upper-triangle FLOPs; the Pallas prefill
    kernel (kernels/flash_prefill) skips those blocks on TPU — see DESIGN.md.
    """
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    q_block = min(q_block, S)
    assert S % q_block == 0
    nq = S // q_block

    spec = P(BATCH, None, "model", None)
    q = constrain(q, spec)
    kf = constrain(_expand_kv(k, H), spec)        # bf16; f32 only in the MXU
    vf = constrain(_expand_kv(v, H), spec)
    qc = jnp.swapaxes(q.reshape(B, nq, q_block, H, D), 0, 1)
    kpos = jnp.arange(S, dtype=jnp.int32)

    @jax.checkpoint
    def chunk(i, qi):                     # qi: [B, q_block, H, D]
        s = jnp.einsum("bqhd,bshd->bhqs", qi, kf,
                       preferred_element_type=jnp.float32) * scale
        s = _score_constraint(s)
        qpos = i * q_block + jnp.arange(q_block, dtype=jnp.int32)
        mask = qpos[:, None] >= kpos[None, :]                 # [q_block, S]
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqs,bshv->bqhv", p, vf,
                          preferred_element_type=jnp.float32)

    out = jax.lax.map(lambda xs: chunk(xs[0], xs[1]), (jnp.arange(nq), qc))
    out = jnp.swapaxes(out, 0, 1).reshape(B, S, H, Dv)
    return constrain(out.astype(v.dtype), spec)


def local_attention(q, k, v, *, window: int, scale: float):
    """Sliding-window causal attention, chunk = window: query chunk i attends
    kv chunks {i-1, i} under the band mask. O(S·2w) compute/memory."""
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    w = min(window, S)
    assert S % w == 0, f"S={S} % window={w} != 0"
    nc = S // w

    spec = P(BATCH, None, "model", None)
    q = constrain(q, spec)
    kh = constrain(_expand_kv(k, H), spec)
    vh = constrain(_expand_kv(v, H), spec)

    qc = jnp.swapaxes(q.reshape(B, nc, w, H, D), 0, 1)        # [nc,B,w,H,D]
    kc = jnp.swapaxes(kh.reshape(B, nc, w, H, D), 0, 1)
    vc = jnp.swapaxes(vh.reshape(B, nc, w, H, Dv), 0, 1)
    # previous chunk (zeros for chunk 0; masked out by the band anyway)
    kprev = jnp.pad(kc, ((1, 0), (0, 0), (0, 0), (0, 0), (0, 0)))[:-1]
    vprev = jnp.pad(vc, ((1, 0), (0, 0), (0, 0), (0, 0), (0, 0)))[:-1]

    qpos = jnp.arange(w, dtype=jnp.int32)[:, None] + w        # within-pair coords
    kpos = jnp.arange(2 * w, dtype=jnp.int32)[None, :]
    band = (qpos >= kpos) & (qpos - kpos < w)                 # causal ∧ window

    @jax.checkpoint
    def chunk(args):
        i, qi, ki, vi, kp, vp = args
        k2 = jnp.concatenate([kp, ki], axis=1)                # [B,2w,H,D]
        v2 = jnp.concatenate([vp, vi], axis=1)
        s = jnp.einsum("bqhd,bshd->bhqs", qi, k2,
                       preferred_element_type=jnp.float32) * scale
        s = _score_constraint(s)
        valid = band & ~((i == 0) & (kpos < w))               # no prev for chunk 0
        s = jnp.where(valid[None, None, :, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqs,bshv->bqhv", p, v2,
                          preferred_element_type=jnp.float32)

    out = jax.lax.map(chunk, (jnp.arange(nc), qc, kc, vc, kprev, vprev))
    out = jnp.swapaxes(out, 0, 1).reshape(B, S, H, Dv)
    return constrain(out.astype(v.dtype), spec)


# ------------------------------------------------------------------- decode
def gqa_decode(q, k_cache, v_cache, length, *, spec=None, **legacy):
    """One-token decode against a [B,S,K,D] cache. q: [B,H,D] -> [B,H,Dv].
    `spec.mode` selects ETAP (paper) vs standard (baseline) pipelines.
    The XLA path streams the cache in its native layout (no reshuffle copy);
    the Pallas path (tests/benchmarks) uses the grouped [BG,...] form.
    spec.kv_splits: split-KV count (None = auto-scheduled on the kernel
    path).  An EXPLICIT kv_splits > 1 on the XLA etap path is honoured
    through the grouped form — that costs the cache reshuffle copy, so it
    is opt-in rather than auto there."""
    spec = attn_spec.coerce(spec, legacy, where="gqa_decode")
    B, H, D = q.shape
    K = k_cache.shape[2]
    n_splits = spec.kv_splits
    want_xla_split = (not spec.use_kernels and spec.mode == "etap"
                      and n_splits is not None and n_splits > 1)
    if spec.use_kernels or want_xla_split:
        qg, kg, vg, restore = gqa_to_grouped(q, k_cache, v_cache)
        lg = jnp.repeat(length, K) if length.ndim else jnp.full((B * K,), length)
        o = decode_attention(qg, kg, vg, lg, spec=spec)
        return restore(o)
    q4 = q.reshape(B, K, H // K, D)
    return gqa_decode_xla(q4, k_cache, v_cache, length, spec=spec)


# --------------------------------------------------------- attention module
def init_attention(key, cfg, dtype):
    H, Kv, hd, D = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "w_q": layers.init_dense(ks[0], D, H * hd, dtype),
        "w_k": layers.init_dense(ks[1], D, Kv * hd, dtype),
        "w_v": layers.init_dense(ks[2], D, Kv * hd, dtype),
        "w_o": layers.init_dense(ks[3], H * hd, D, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(params, cfg, x, positions):
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    lead = x.shape[:-1]
    q = layers.dense(x, params["w_q"]).reshape(*lead, H, hd)
    k = layers.dense(x, params["w_k"]).reshape(*lead, Kv, hd)
    v = layers.dense(x, params["w_v"]).reshape(*lead, Kv, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_train(params, cfg, x, positions, *, return_cache: bool = False):
    """x: [B,S,D] -> [B,S,D]. Full or local causal attention per config."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    scale = cfg.resolved_head_dim ** -0.5
    if cfg.attention_kind == "local":
        o = local_attention(q, k, v, window=cfg.window_size, scale=scale)
    else:
        o = causal_attention(q, k, v, scale=scale)
    out = layers.dense(o.reshape(*x.shape[:-1], -1), params["w_o"])
    if return_cache:
        return out, {"k": k, "v": v}
    return out


def attention_decode(params, cfg, x, cache, pos, *, spec=None, **legacy):
    """x: [B,D] one token; cache: {"k","v"}: [B,S,K,hd] (ring buffer of size
    window for local attention). Returns (out [B,D], new cache).
    spec.kv_splits: split-KV count for the kernel decode path (None = auto);
    the per-layer scale and cfg.use_kernels are folded into the spec here."""
    spec = attn_spec.coerce(spec, legacy, where="attention_decode")
    B, D = x.shape
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, cfg, x[:, None, :], positions)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                        # [B,H,hd],[B,K,hd]
    Smax = cache["k"].shape[1]
    K = k.shape[1]
    scale = cfg.resolved_head_dim ** -0.5
    mesh = compat.get_mesh()
    seq_shard = (cfg.attention_kind == "full" and not cfg.use_kernels
                 and seq_shardable(Smax, mesh))
    if seq_shard:
        # big full-attention cache: S-sharded over `model` (shard_map partial
        # softmax + tiny stats exchange) — same scheme as MLA decode.
        q4 = q.reshape(B, K, cfg.num_heads // K, cfg.resolved_head_dim)
        o, kc, vc = seq_sharded_gqa_decode(q4, cache["k"], cache["v"], k, v,
                                           pos, scale=scale)
    else:
        slot = pos % Smax if cfg.attention_kind == "local" else pos
        kc = jax.lax.dynamic_update_index_in_dim(cache["k"], k, slot, 1)
        vc = jax.lax.dynamic_update_index_in_dim(cache["v"], v, slot, 1)
        length = jnp.minimum(pos + 1, Smax)
        o = gqa_decode(q, kc, vc, jnp.full((B,), length, jnp.int32),
                       spec=spec.replace(scale=scale,
                                         use_kernels=cfg.use_kernels))
    out = layers.dense(o.reshape(B, -1), params["w_o"])
    return out, {"k": kc, "v": vc}


def _append_paged_kv(cache, table, lengths, k, v):
    """Append one row (or a [B,C,...] chunk) of K/V into the paged GQA
    cache, quantizing on write when the cache carries (scale, zp) pools
    (DESIGN.md §11).  Returns the updated cache dict."""
    chunked = k.ndim == 4                          # [B,C,K,hd] vs [B,K,hd]
    if "k_sz" in cache:
        app = (paged_cache.append_chunk_quant if chunked
               else paged_cache.append_rows_quant)
        kc, k_sz = app(cache["k"], cache["k_sz"], table, lengths, k)
        vc, v_sz = app(cache["v"], cache["v_sz"], table, lengths, v)
        return {"k": kc, "v": vc, "k_sz": k_sz, "v_sz": v_sz}
    app = paged_cache.append_chunk if chunked else paged_cache.append_rows
    return {"k": app(cache["k"], table, lengths, k),
            "v": app(cache["v"], table, lengths, v)}


def _gather_paged_kv(cache, table):
    """Dense [B,S,K,hd] views of the paged GQA cache, dequantized when the
    pools hold codes (the GQA paged path is gather-based — see
    attention_decode_paged; MLA streams its pool in place instead)."""
    kd = paged_cache.gather_blocks(cache["k"], table)
    vd = paged_cache.gather_blocks(cache["v"], table)
    if "k_sz" in cache:
        kd = paged_cache.dequantize_rows(
            kd, paged_cache.gather_blocks(cache["k_sz"], table))
        vd = paged_cache.dequantize_rows(
            vd, paged_cache.gather_blocks(cache["v_sz"], table))
    return kd, vd


def attention_decode_paged(params, cfg, x, cache, table, lengths, *,
                           spec=None, **legacy):
    """One-token GQA decode against a PAGED cache: {"k","v"} pools of shape
    [num_blocks, page, K, hd], a shared block table and per-sequence
    lengths (ragged — each new token lands at its own `lengths[b]`).

    The new KV row is appended through the table; attention then gathers
    the pool into the native dense [B,S,K,hd] layout and reuses the
    existing GQA paths — correctness-first: the GQA pool carries a kv-head
    axis the grouped paged kernels don't stride over (yet), so only MLA
    (the paper's serving path) streams its pool in place.  Local-window
    attention keeps its dense ring buffer (a window never pages)."""
    spec = attn_spec.coerce(spec, legacy, where="attention_decode_paged")
    assert cfg.attention_kind == "full", \
        "paged cache supports full attention (local windows stay dense)"
    B, D = x.shape
    positions = lengths[:, None].astype(jnp.int32)
    q, k, v = _project_qkv(params, cfg, x[:, None, :], positions)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                       # [B,H,hd],[B,K,hd]
    new_cache = _append_paged_kv(cache, table, lengths, k, v)
    kd, vd = _gather_paged_kv(new_cache, table)               # [B,S,K,hd]
    if "k_sz" in cache:
        q = q.astype(jnp.float32)         # match the dequantized fp32 rows
    o = gqa_decode(q, kd, vd, lengths + 1,
                   spec=spec.replace(scale=cfg.resolved_head_dim ** -0.5,
                                     use_kernels=cfg.use_kernels,
                                     block=cache["k"].shape[1]))
    # back to the model dtype: under a quantized layout the dequantized
    # rows (and hence gqa_decode's output) are fp32 — without the cast
    # every decode step's residual stream would silently promote
    out = layers.dense(o.reshape(B, -1).astype(x.dtype), params["w_o"])
    return out, new_cache


def _attention_chunk(params, cfg, x, cache, table, lengths, positions):
    """Shared body of chunked prefill and draft verification over the paged
    GQA cache: append the chunk's K/V rows, gather, run the masked
    chunk-vs-context product.  ``positions`` [B,C] drives rope AND the
    per-row causal horizon (key position p live for row c iff
    p <= positions[b, c]) — prefill passes start + row index, verification
    passes the explicit draft-row horizons (identical on linear chains)."""
    B, C, D = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)  # [B,C,H,hd],[B,C,K,hd]
    new_cache = _append_paged_kv(cache, table, lengths, k, v)
    kd, vd = _gather_paged_kv(new_cache, table)               # [B,S,K,hd]
    H = cfg.num_heads
    S = kd.shape[1]
    kh = _expand_kv(kd, H)
    vh = _expand_kv(vd, H)
    if "k_sz" in cache:
        q = q.astype(jnp.float32)         # match the dequantized fp32 rows
    s = jnp.einsum("bchd,bshd->bhcs", q, kh,
                   preferred_element_type=jnp.float32) * cfg.resolved_head_dim ** -0.5
    kpos = jnp.arange(S, dtype=jnp.int32)
    valid = kpos[None, None, :] <= positions[:, :, None]      # [B,C,S]
    s = jnp.where(valid[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhcs,bshv->bchv", p, vh,
                   preferred_element_type=jnp.float32).astype(v.dtype)
    out = layers.dense(o.reshape(B, C, -1), params["w_o"])
    return out, new_cache


def attention_prefill_chunk(params, cfg, x, cache, table, lengths, *,
                            spec=None, **legacy):
    """CHUNKED prefill of C prompt tokens against a PAGED GQA cache.

    x: [B,C,D]; cache: {"k","v"} pools [num_blocks, page, K, hd]; table:
    [B,max_blocks]; lengths: [B] tokens already written (the chunk start).
    The chunk's K/V rows are appended through the table first; attention
    then gathers the pool into the native dense [B,S,K,hd] layout and runs
    a causally-masked chunk-vs-context product — same correctness-first
    gather route as :func:`attention_decode_paged` (the GQA pool carries a
    kv-head axis the paged kernels don't stride over; MLA, the paper's
    serving path, streams its pool in place via core.etap).  The spec is
    accepted for entry-point parity; this dense-mask route has no knobs."""
    assert cfg.attention_kind == "full", \
        "paged cache supports full attention (local windows stay dense)"
    attn_spec.coerce(spec, legacy, where="attention_prefill_chunk")
    C = x.shape[1]
    positions = lengths[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    return _attention_chunk(params, cfg, x, cache, table, lengths, positions)


def attention_verify_chunk(params, cfg, x, cache, table, lengths, qpos, *,
                           spec=None, **legacy):
    """DRAFT VERIFICATION over the paged GQA cache (DESIGN.md §14): score k
    draft rows in one chunked-prefill-shaped pass.  qpos: [B,k] each draft
    row's absolute position; a linear chain (lengths[:, None] + arange(k))
    makes this bitwise identical to :func:`attention_prefill_chunk`.
    Rejected rows are rewound by the scheduler via BlockPool.truncate."""
    assert cfg.attention_kind == "full", \
        "paged cache supports full attention (local windows stay dense)"
    attn_spec.coerce(spec, legacy, where="attention_verify_chunk")
    return _attention_chunk(params, cfg, x, cache, table, lengths,
                            qpos.astype(jnp.int32))


def init_attention_cache(cfg, batch: int, max_len: int, dtype):
    Kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    n = min(max_len, cfg.window_size) if cfg.attention_kind == "local" else max_len
    return {"k": jnp.zeros((batch, n, Kv, hd), dtype),
            "v": jnp.zeros((batch, n, Kv, hd), dtype)}


def init_attention_cache_paged(cfg, layout, dtype, kv_dtype: str = "fp"):
    Kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (layout.num_blocks, layout.block_size, Kv, hd)
    qdt = paged_cache.quant_dtype(kv_dtype)
    if qdt is None:
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    # per-row (scale, zp) PER KV HEAD: the quantization granule is the
    # head's hd-vector (DESIGN.md §11); scale 1 round-trips the zero init
    sz0 = jnp.concatenate(
        [jnp.ones(shape[:3] + (1,), jnp.float32),
         jnp.zeros(shape[:3] + (1,), jnp.float32)], -1)
    return {"k": jnp.zeros(shape, qdt), "v": jnp.zeros(shape, qdt),
            "k_sz": sz0, "v_sz": sz0}
