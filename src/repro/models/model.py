"""Config-driven LM: embedding -> grouped/scanned block stack -> head.

Layers are *grouped* so jax.lax.scan compiles each distinct block body once:
 - homogeneous stacks (dense/MoE/SSM) scan a single stacked group;
 - periodic hybrids (recurrentgemma's rglru,rglru,attn cycle) scan a stacked
   "superblock" group + unrolled remainder;
 - irregular prefixes (deepseek's 3 dense + 58 MoE layers) become run-length
   segments.
The KV/state cache pytree mirrors the grouping, so decode scans layers with
(params, cache) as scan xs and the updated cache as scan ys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import attn_spec
from repro.core import mla as mla_mod
from repro.models import attention, frontend, layers, mamba, moe, rglru
from repro.runtime import telemetry
from repro.sharding.rules import BATCH, constrain

AUX_KEYS = ("load_balance", "router_z")


def _scan_layers(body, x, xs):
    """lax.scan over a stacked layer group — unless a kernel profiler is
    installed (runtime/telemetry.py) and the carry is concrete.  scan traces
    its body, so every attention launch inside sees tracer operands and the
    per-launch timing hook in core/attn_spec.attn_entry must skip it
    (tracers can't be block_until_ready'd).  A Python loop keeps each layer's
    launch concrete and timeable; profiling mode has already given up the
    fused outer jit, so the extra per-layer dispatch only moves time between
    buckets, never changes results."""
    if (telemetry.profiler() is not None
            and not isinstance(x, jax.core.Tracer)):
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            x, y = body(x, jax.tree.map(lambda a, i=i: a[i], xs))
            ys.append(y)
        return x, jax.tree.map(lambda *rows: jnp.stack(rows), *ys)
    return jax.lax.scan(body, x, xs)


# ------------------------------------------------------------- layer groups
def signatures(cfg) -> list:
    """(kind, is_moe) per layer."""
    return [(k, bool(cfg.moe_layer(i)) and k == "attn")
            for i, k in enumerate(cfg.layer_kinds())]


def _rle(seq):
    runs = []
    for s in seq:
        if runs and runs[-1][0] == s:
            runs[-1][1] += 1
        else:
            runs.append([s, 1])
    return [(s, n) for s, n in runs]


def layer_groups(cfg) -> list:
    """Static plan: list of {"sigs": [sig,...], "n": repeats}."""
    sigs = signatures(cfg)
    runs = _rle(sigs)
    if len(runs) <= 4:
        return [{"sigs": [s], "n": n} for s, n in runs]
    for p in range(1, 7):                              # periodic superblock
        if all(sigs[i] == sigs[i % p] for i in range(len(sigs))):
            n = len(sigs) // p
            groups = [{"sigs": sigs[:p], "n": n}]
            groups += [{"sigs": [s], "n": 1} for s in sigs[n * p:]]
            return groups
    return [{"sigs": [s], "n": 1} for s in sigs]       # fallback: unrolled


# -------------------------------------------------------------- block defs
def _init_block(key, cfg, sig, dtype):
    kind, is_moe = sig
    k1, k2, k3, k4 = jax.random.split(key, 4)
    D = cfg.d_model
    p = {"norm1": jnp.zeros((D,), dtype)}
    if kind == "attn":
        p["mix"] = (mla_mod.init_mla(k1, cfg, dtype) if cfg.attention_kind == "mla"
                    else attention.init_attention(k1, cfg, dtype))
    elif kind == "rglru":
        p["mix"] = rglru.init_rglru(k1, cfg, dtype)
    elif kind == "ssm":
        p["mix"] = mamba.init_mamba(k1, cfg, dtype)
        return p                                        # mamba block has no FFN
    else:
        raise ValueError(kind)
    p["norm2"] = jnp.zeros((D,), dtype)
    p["ffn"] = moe.init_moe(k2, cfg, dtype) if is_moe else \
        layers.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def _block_seq(params, cfg, sig, x, positions, collect_cache: bool):
    """One block over a full sequence. Returns (x, aux, cache_rows_or_{})."""
    kind, is_moe = sig
    aux = _zero_aux()
    cache = {}
    h = layers.rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind == "attn":
        fn = mla_mod.mla_train if cfg.attention_kind == "mla" else attention.attention_train
        if collect_cache:
            mixed, cache = fn(params["mix"], cfg, h, positions, return_cache=True)
        else:
            mixed = fn(params["mix"], cfg, h, positions)
    elif kind == "rglru":
        if collect_cache:
            mixed, cache = rglru.rglru_seq(params["mix"], cfg, h, return_state=True)
        else:
            mixed = rglru.rglru_seq(params["mix"], cfg, h)
    else:  # ssm
        if collect_cache:
            mixed, cache = mamba.mamba_seq(params["mix"], cfg, h, return_state=True)
        else:
            mixed = mamba.mamba_seq(params["mix"], cfg, h)
    x = x + mixed
    if kind == "ssm":
        return x, aux, cache
    h2 = layers.rms_norm(x, params["norm2"], cfg.norm_eps)
    if is_moe:
        f, aux = moe.moe_ffn(params["ffn"], cfg, h2)
    else:
        f = layers.mlp(params["ffn"], h2)
    return x + f, aux, cache


def _block_decode(params, cfg, sig, x, cache, pos, spec,
                  cache_layout="dense", block_table=None, lengths=None):
    """One block, one token. x: [B,D]. Returns (x, new_cache).
    cache_layout "paged": the attention cache is a block pool; `pos` is
    replaced by per-sequence `lengths` + the shared `block_table`."""
    kind, is_moe = sig
    h = layers.rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind == "attn":
        if cache_layout == "paged":
            fn = (mla_mod.mla_decode_paged if cfg.attention_kind == "mla"
                  else attention.attention_decode_paged)
            mixed, cache = fn(params["mix"], cfg, h, cache, block_table,
                              lengths, spec=spec)
        elif cfg.attention_kind == "mla":
            mixed, cache = mla_mod.mla_decode(params["mix"], cfg, h, cache,
                                              pos, spec=spec)
        else:
            mixed, cache = attention.attention_decode(params["mix"], cfg, h,
                                                      cache, pos, spec=spec)
    elif kind == "rglru":
        mixed, cache = rglru.rglru_decode(params["mix"], cfg, h, cache)
    else:
        mixed, cache = mamba.mamba_decode(params["mix"], cfg, h, cache)
    x = x + mixed
    if kind == "ssm":
        return x, cache
    h2 = layers.rms_norm(x, params["norm2"], cfg.norm_eps)
    if is_moe:
        # serving: one group of B tokens, dropless routing
        f, _ = moe.moe_ffn(params["ffn"], cfg, h2[None], dropless=True)
        f = f[0]
    else:
        f = layers.mlp(params["ffn"], h2)
    return x + f, cache


def _init_block_cache(cfg, sig, batch: int, max_len: int, dtype):
    kind, _ = sig
    if kind == "attn":
        if cfg.attention_kind == "mla":
            return mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
        return attention.init_attention_cache(cfg, batch, max_len, dtype)
    if kind == "rglru":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    return mamba.init_mamba_cache(cfg, batch, dtype)


# ------------------------------------------------------------------- model
def init(rng, cfg):
    dtype = cfg.jax_dtype
    groups = layer_groups(cfg)
    keys = jax.random.split(rng, len(groups) + 2)
    params: dict = {
        "embed": layers.init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    fe = frontend.init_frontend(keys[1], cfg, dtype)
    if fe is not None:
        params["frontend"] = fe
    gp = []
    for g, key in zip(groups, keys[2:], strict=True):
        gkeys = jax.random.split(key, g["n"])
        def one(k, g=g):
            ks = jax.random.split(k, len(g["sigs"]))
            return {f"b{j}": _init_block(ks[j], cfg, s, dtype)
                    for j, s in enumerate(g["sigs"])}
        gp.append(jax.vmap(one)(gkeys))
    params["groups"] = gp
    return params


def _embed_inputs(params, cfg, batch):
    if "embeds" in batch:
        return frontend.apply_frontend(params["frontend"], batch["embeds"])
    return layers.embed(params["embed"], batch["tokens"])


def forward(params, cfg, batch, *, collect_cache: bool = False):
    """batch: {"tokens": [B,S]} or {"embeds": [B,S,Df], "targets": [B,S]}.
    Returns (logits [B,S,V], aux, cache or None)."""
    x = _embed_inputs(params, cfg, batch)
    # activations ride the batch axes; d_model replicated between blocks
    x = constrain(x, P(BATCH, None, None))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    groups = layer_groups(cfg)
    aux_total = _zero_aux()
    caches = []

    for g, gparams in zip(groups, params["groups"], strict=True):
        def body(carry, xs, g=g):
            x, aux = carry
            lp = xs
            crows = {}
            for j, sig in enumerate(g["sigs"]):
                fn = _block_seq
                if cfg.remat:
                    fn = jax.checkpoint(fn, static_argnums=(1, 2, 5))
                x, a, c = fn(lp[f"b{j}"], cfg, sig, x, positions, collect_cache)
                # sequence parallelism: the residual stream (and hence the
                # per-layer remat residuals) is S-sharded over `model`.
                # Attention-free stacks (mamba) keep d_inner on `model`
                # instead — alternating layouts would round-trip the
                # activations through collectives every layer (§Perf M3).
                if cfg.attention_kind != "none":
                    x = constrain(x, P(BATCH, "model", None))
                else:
                    x = constrain(x, P(BATCH, None, None))
                crows[f"b{j}"] = c
                aux = {k: aux[k] + a[k] for k in AUX_KEYS}
            return (x, aux), crows

        (x, aux_total), gc = jax.lax.scan(body, (x, aux_total), gparams)
        caches.append(gc)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain(layers.unembed(params["embed"], x),
                       P(BATCH, None, "model"))   # vocab-sharded logits
    return logits, aux_total, (caches if collect_cache else None)


def loss_fn(params, cfg, batch):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, aux, _ = forward(params, cfg, batch)
    targets = batch.get("targets", batch.get("tokens"))
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = targets[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    total = loss + 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
    return total, {"nll": loss, **aux}


# ----------------------------------------------------------------- serving
def init_cache(cfg, batch: int, max_len: int):
    dtype = cfg.jax_dtype
    groups = layer_groups(cfg)

    def stack(leaf_fn, n):
        one = leaf_fn()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)

    return [
        {f"b{j}": stack(lambda s=s: _init_block_cache(cfg, s, batch, max_len, dtype),
                        g["n"])
         for j, s in enumerate(g["sigs"])}
        for g in groups
    ]


def init_paged_cache(cfg, layout, kv_dtype: str = "fp"):
    """Paged serving cache: one KV block pool per layer (stacked per layer
    group, like :func:`init_cache`), all layers sharing ONE block table
    owned by the scheduler (runtime/paged_cache.BlockPool) — every layer
    sees the same sequence structure, so block ids are reused across
    layers and only the pools differ.  Attention-only stacks: recurrent /
    SSM state is per-sequence, not per-token — nothing to page.

    kv_dtype: "fp" (config dtype) | "int8" | "fp8" — quantized layouts
    store code pools plus per-row (scale, zp) pools under "*_sz" keys
    (DESIGN.md §11); every downstream path (decode, chunked prefill, COW
    block copy) keys off the cache dict, so the layout choice is made
    exactly once, here."""
    dtype = cfg.jax_dtype
    for kind in cfg.layer_kinds():
        if kind != "attn":
            raise ValueError(
                f"paged cache requires an attention-only stack (got {kind})")
    groups = layer_groups(cfg)

    def one(sig):
        if cfg.attention_kind == "mla":
            return mla_mod.init_mla_cache_paged(cfg, layout, dtype,
                                                kv_dtype=kv_dtype)
        return attention.init_attention_cache_paged(cfg, layout, dtype,
                                                    kv_dtype=kv_dtype)

    def stack(leaf_fn, n):
        one_c = leaf_fn()
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                            one_c)

    return [
        {f"b{j}": stack(lambda s=s: one(s), g["n"])
         for j, s in enumerate(g["sigs"])}
        for g in groups
    ]


def paged_row_bytes(cfg, kv_dtype: str = "fp") -> int:
    """KV-cache bytes ONE token costs across the whole layer stack in a
    paged cache of the given layout — the quantity the serve loop's
    byte-budget capacity accounting divides by (DESIGN.md §11).  MLA: one
    latent_dim row per layer; GQA: K heads × head_dim for K and V each
    (each head is its own quantization granule, so each carries its own
    (scale, zp) overhead)."""
    from repro.runtime.paged_cache import row_bytes
    n_layers = len(cfg.layer_kinds())
    if cfg.attention_kind == "mla":
        return n_layers * row_bytes(cfg.mla.latent_dim, kv_dtype,
                                    cfg.jax_dtype)
    Kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    per_stream = row_bytes(Kv * hd, kv_dtype, cfg.jax_dtype, granules=Kv)
    return n_layers * 2 * per_stream                      # K and V pools


def copy_paged_block(cache, src: int, dst: int):
    """Copy-on-write device copy over the whole paged cache pytree:
    duplicate physical block `src` into `dst` in every layer's pool (leaves
    carry a leading stacked-layer axis, [n_layers, num_blocks, bs, *F]).
    The serve scheduler calls this on the COW pair returned by
    BlockPool.admit_shared when a cached prefix ends mid-block, BEFORE any
    chunk is appended to the new slot (DESIGN.md §10) — all layers share
    one block table, so one (src, dst) pair covers the whole stack."""
    return jax.tree.map(lambda p: p.at[:, dst].set(p[:, src]), cache)


def read_paged_blocks(cache, ids):
    """HOST copies of physical blocks `ids` from every pool leaf of a paged
    cache pytree (leaves: [n_layers, num_blocks, bs, *F]) — the device→host
    leg of preemption-by-swap (DESIGN.md §12).  Returns a matching numpy
    pytree with [n_layers, len(ids), bs, *F] leaves, bitwise copies in the
    pool's storage dtype (codes AND sz scale pools both ride along, so a
    quantized sequence swaps losslessly).  np.asarray forces the host sync:
    the caller frees the device blocks right after, so the copy must be
    materialized, not a lazy view of in-flight state."""
    idx = np.asarray(ids, np.int32)
    return jax.tree.map(lambda p: np.asarray(p[:, idx]), cache)


def write_paged_blocks(cache, ids, rows):
    """Write host block rows (a pytree from :func:`read_paged_blocks`) back
    into physical blocks `ids` of every pool leaf — the host→device leg of
    swap restoration.  Dtypes already match (the host copy kept the pool's
    storage dtype), so the round-trip is bitwise and a restored sequence
    decodes exactly as if it had never been preempted."""
    idx = jnp.asarray(np.asarray(ids, np.int32))
    return jax.tree.map(
        lambda p, r: p.at[:, idx].set(jnp.asarray(r).astype(p.dtype)),
        cache, rows)


def _block_prefill_chunk(params, cfg, sig, x, cache, table, lengths, spec,
                         qpos=None):
    """One block over a C-token prompt chunk against the paged cache.
    x: [B,C,D].  Paged caches are attention-only (init_paged_cache), so
    the recurrent/SSM kinds never reach here.  qpos [B,C] switches the
    attention layer to its draft-verification twin (explicit per-row
    causal horizon — DESIGN.md §14); everything else is identical."""
    kind, is_moe = sig
    assert kind == "attn", kind
    h = layers.rms_norm(x, params["norm1"], cfg.norm_eps)
    if qpos is None:
        fn = (mla_mod.mla_prefill_chunk if cfg.attention_kind == "mla"
              else attention.attention_prefill_chunk)
        mixed, cache = fn(params["mix"], cfg, h, cache, table, lengths,
                          spec=spec)
    else:
        fn = (mla_mod.mla_verify_chunk if cfg.attention_kind == "mla"
              else attention.attention_verify_chunk)
        mixed, cache = fn(params["mix"], cfg, h, cache, table, lengths,
                          qpos, spec=spec)
    x = x + mixed
    h2 = layers.rms_norm(x, params["norm2"], cfg.norm_eps)
    if is_moe:
        # serving semantics: DROPLESS routing, same as decode_step — a
        # prompt token is never dropped at inference. This deliberately
        # diverges from the capacity-dropped routing of the training-path
        # :func:`forward` that single-shot prefill reuses, so the chunked ==
        # single-shot equivalence oracle holds exactly only for non-MoE
        # stacks (or capacities that never drop, e.g. reduced configs);
        # tests/test_prefill_chunk.py checks MoE via chunked-vs-one-chunk
        # self-consistency instead.
        f, _ = moe.moe_ffn(params["ffn"], cfg, h2, dropless=True)
    else:
        f = layers.mlp(params["ffn"], h2)
    return x + f, cache


def prefill_chunk(params, cfg, cache, tokens, block_table, lengths, *,
                  spec=None, **legacy):
    """CHUNKED paged prefill: run C prompt tokens per sequence directly
    against the block-pool serving cache (DESIGN.md §9).

    tokens: [B,C] the chunk (token c sits at absolute position
    lengths[b] + c); cache: the pytree from :func:`init_paged_cache`;
    block_table: [B,max_blocks]; lengths: [B] tokens already written — the
    caller (launch/serve.py) advances it by C between chunks
    (BlockPool.extend).  Each attention layer appends its chunk rows into
    its pool and attends causally over chunk + previously-written context,
    so there is NO dense staging cache and no post-hoc scatter — admission
    prefill becomes a sequence of per-chunk appends whose peak memory is
    one chunk, not one prompt.  Because `lengths` is the chunk's absolute
    start offset (positions, causal masking and the pool write all derive
    from it), a prefill may START at any nonzero offset: prefix-cache hits
    (DESIGN.md §10) map the matched blocks into the table, set lengths to
    the match length, and only the unmatched prompt TAIL ever runs through
    here.  Returns (logits [B,C,V], new cache); the final chunk's
    last-position logits seed the first decode token."""
    spec = attn_spec.coerce(spec, legacy, where="prefill_chunk")
    return _chunk_forward(params, cfg, cache, tokens, block_table, lengths,
                          spec)


def _chunk_forward(params, cfg, cache, tokens, block_table, lengths, spec,
                   qpos=None):
    """Shared chunk-shaped forward of prefill_chunk and verify_step."""
    x = constrain(layers.embed(params["embed"], tokens), P(BATCH, None, None))
    groups = layer_groups(cfg)
    new_caches = []
    for g, gparams, gcache in zip(groups, params["groups"], cache,
                                  strict=True):
        def body(x, xs, g=g):
            lp, lc = xs
            ncs = {}
            for j, sig in enumerate(g["sigs"]):
                x, nc = _block_prefill_chunk(lp[f"b{j}"], cfg, sig, x,
                                             lc[f"b{j}"], block_table,
                                             lengths, spec, qpos)
                ncs[f"b{j}"] = nc
            return x, ncs
        x, gc_new = _scan_layers(body, x, (gparams, gcache))
        new_caches.append(gc_new)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x)
    return logits, new_caches


def verify_step(params, cfg, cache, tokens, block_table, lengths, *,
                spec=None, qpos=None, **legacy):
    """Score k draft tokens per sequence in ONE chunked-prefill-shaped pass
    (DESIGN.md §14) — the verification half of draft-then-verify decoding.

    tokens: [B,k] — row 0 is each sequence's last committed token, rows
    1..k-1 the draft continuation; the pass both APPENDS their KV rows into
    the paged pool at `lengths` (in-cache verification — the accepted
    prefix's rows are already where decode needs them) and returns logits
    for every draft position.  qpos: [B,k] per-row absolute positions; None
    → the linear chain lengths[:, None] + arange(k), under which this is
    bitwise identical to :func:`prefill_chunk` on the same tokens.  The
    caller pre-extends the block budget (BlockPool.extend) and rewinds the
    rejected tail afterwards (BlockPool.truncate(..., free_blocks=False)).
    Returns (logits [B,k,V], new cache)."""
    spec = attn_spec.coerce(spec, legacy, where="verify_step")
    if qpos is None:
        k = tokens.shape[1]
        qpos = lengths[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    return _chunk_forward(params, cfg, cache, tokens, block_table, lengths,
                          spec, qpos.astype(jnp.int32))


def _pad_cache_rows(cfg, sig, cache_rows, max_len, batch_s):
    """Pad per-layer prefill cache rows out to the serving cache layout."""
    kind, _ = sig
    if kind in ("rglru", "ssm"):
        return cache_rows
    if cfg.attention_kind == "mla":
        c = cache_rows["c"]
        pad = max_len - c.shape[1]
        return {"c": jnp.pad(c, ((0, 0), (0, pad), (0, 0)))}
    n = min(max_len, cfg.window_size) if cfg.attention_kind == "local" else max_len
    out = {}
    for key in ("k", "v"):
        rows = cache_rows[key]                          # [B,S,K,hd]
        S = rows.shape[1]
        if cfg.attention_kind == "local" and S > n:
            rows = rows[:, -n:]
        pad = n - rows.shape[1]
        out[key] = jnp.pad(rows, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out


def prefill(params, cfg, batch, max_len: int):
    """Run the prompt, build the serving cache. Returns (last_logits, cache, pos)."""
    logits, _, caches = forward(params, cfg, batch, collect_cache=True)
    S = logits.shape[1]
    groups = layer_groups(cfg)
    padded = []
    for g, gc in zip(groups, caches, strict=True):
        padded.append({f"b{j}": jax.vmap(
            lambda rows, s=s: _pad_cache_rows(cfg, s, rows, max_len, S))(gc[f"b{j}"])
            for j, s in enumerate(g["sigs"])})
    return logits[:, -1, :], padded, S


def decode_step(params, cfg, cache, tokens, pos, *, spec=None,
                cache_layout: str = "dense",
                block_table=None, lengths=None, **legacy):
    """One serving step. tokens: [B] int32; pos: scalar index of the new token.
    Returns (logits [B,V], new_cache). spec: one AttnSpec carrying mode /
    kv_splits / rescale for every attention layer (legacy mode=/kv_splits=
    keywords shim through attn_spec.coerce).  spec.kv_splits None =
    auto-scheduled per layer geometry — serving picks up split-KV with zero
    caller changes; exception: the native-layout GQA XLA path only splits
    on an explicit count, since splitting there costs a cache reshuffle
    copy — see models/attention.gqa_decode.

    cache_layout "paged" (the serving default in launch/serve.py): `cache`
    is the pool pytree from :func:`init_paged_cache`, and `block_table`
    [B, max_blocks] + per-sequence `lengths` [B] replace the shared scalar
    `pos` — ragged sequences decode in one batch (continuous batching)."""
    spec = attn_spec.coerce(spec, legacy, where="decode_step")
    assert cache_layout in ("dense", "paged"), cache_layout
    if cache_layout == "paged":
        assert block_table is not None and lengths is not None
    x = constrain(layers.embed(params["embed"], tokens), P(BATCH, None))
    groups = layer_groups(cfg)
    new_caches = []
    for g, gparams, gcache in zip(groups, params["groups"], cache,
                                  strict=True):
        def body(x, xs, g=g):
            lp, lc = xs
            ncs = {}
            for j, sig in enumerate(g["sigs"]):
                x, nc = _block_decode(lp[f"b{j}"], cfg, sig, x, lc[f"b{j}"],
                                      pos, spec,
                                      cache_layout=cache_layout,
                                      block_table=block_table,
                                      lengths=lengths)
                ncs[f"b{j}"] = nc
            return x, ncs
        x, gc_new = _scan_layers(body, x, (gparams, gcache))
        new_caches.append(gc_new)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["embed"], x)
    return logits, new_caches


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
