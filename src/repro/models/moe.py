"""Mixture-of-Experts FFN with sort-based top-k dispatch.

Instead of the GShard one-hot dispatch tensor [G,S,E,C] (O(tokens·E·C) — TBs
at our shapes), token→slot assignment is computed with a stable argsort over
expert ids (O(tokens·k)), then experts are fed via *batched local gathers*:

    x [G,S,D] (G sharded on data)  --gather-->  xe [G,E,C,D]
    xe resharded G->E via with_sharding_constraint (GSPMD emits all-to-all)
    expert FFN einsum with weights [E(model),D,F]  (expert parallelism)
    ye resharded E->G (all-to-all back), combine via local gather + gate sum

Capacity semantics match GShard: per group, each expert takes at most C
tokens, earlier (token, choice) pairs win (stable sort), overflow is dropped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.sharding.rules import constrain


def init_moe(key, cfg, dtype):
    m = cfg.moe
    D = cfg.d_model
    F = m.d_ff_expert or cfg.d_ff
    E = m.num_experts
    ks = jax.random.split(key, 5)
    std = D ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * std).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * std).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32) * (F ** -0.5)).astype(dtype),
    }
    if m.shared_expert:
        p["shared"] = layers.init_mlp(ks[4], D, F, dtype)
    return p


def _capacity(tokens_per_group: int, cfg) -> int:
    m = cfg.moe
    c = int(m.top_k * tokens_per_group * m.capacity_factor / m.num_experts)
    return max(4, -(-c // 4) * 4)   # >=4, rounded up to a multiple of 4


def _assign_slots(idx_k, E: int, C: int):
    """idx_k: [G, S, k] expert choices. Returns
    slot_of_choice [G, S*k] in [0, E*C] (E*C = dropped) and
    token_of_slot [G, E*C] in [0, S*k] (S*k = empty slot sentinel)."""
    G, S, k = idx_k.shape
    T = S * k
    flat_e = idx_k.reshape(G, T)
    order = jnp.argsort(flat_e, axis=-1, stable=True)           # [G,T]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # position within expert, in sorted order: i - first index of this expert
    ar = jnp.arange(T, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((G, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=-1)
    first = jax.lax.associative_scan(
        jnp.maximum, jnp.where(change, ar[None, :], 0), axis=-1)
    pos_sorted = ar[None, :] - first                            # [G,T]
    # back to (token, choice) order
    pos = jnp.zeros_like(pos_sorted).at[
        jnp.arange(G)[:, None], order].set(pos_sorted)
    dropped = pos >= C
    slot = jnp.where(dropped, E * C, flat_e * C + jnp.minimum(pos, C - 1))
    # invert: token index feeding each expert slot (S = empty-slot sentinel);
    # dropped pairs write into bucket E*C, sliced off below.
    token_of_slot = jnp.full((G, E * C + 1), S, jnp.int32).at[
        jnp.arange(G)[:, None], slot].set(ar[None, :] // k)
    return slot.astype(jnp.int32), token_of_slot[:, : E * C]


def moe_ffn(params, cfg, x, *, dropless: bool = False):
    """x: [G,S,D] -> (out [G,S,D], aux losses). G rides the data axis; the
    expert dimension rides the model axis (expert parallelism).
    dropless=True (serving): capacity = S, nothing dropped."""
    m = cfg.moe
    G, S, D = x.shape
    E, k = m.num_experts, m.top_k
    if dropless:
        # serving: bounded-overflow capacity — 4x the balanced per-expert
        # load. C = S would be truly dropless but makes every expert
        # process up to ALL tokens (E/topk-fold FLOPs waste) and forces an
        # E*C*D-sized combine gather (§Perf iteration D2).
        C = min(S, max(k, 4 * -(-k * S // E)))
    else:
        C = min(_capacity(S, cfg), max(4, S))

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(gates, k)                     # [G,S,k]

    slot, token_of_slot = _assign_slots(idx_k, E, C)            # [G,T],[G,E*C]
    # gather expert inputs (sentinel token S -> zero row)
    xpad = jnp.concatenate([x, jnp.zeros((G, 1, D), x.dtype)], axis=1)
    xe = jnp.take_along_axis(xpad, token_of_slot[..., None], axis=1)
    xe = xe.reshape(G, E, C, D)
    # reshard G->E sharded (GSPMD all-to-all) for expert parallelism.
    # Serving (dropless) uses the EP-over-data layout matching the serve
    # weight profile; training EP rides the model axis.
    xe = constrain(xe, P(None, "data" if dropless else "model", None, None))

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])      # [G,E,C,D]
    ye = constrain(ye, P(("pod", "data"), None, None, None))

    # combine: per (token, choice) gather its slot's output, weight by gate
    ypad = jnp.concatenate([ye.reshape(G, E * C, D),
                            jnp.zeros((G, 1, D), ye.dtype)], axis=1)
    yk = jnp.take_along_axis(ypad, slot[..., None], axis=1)     # [G,T,D]
    yk = yk.reshape(G, S, k, D)
    out = jnp.sum(yk.astype(jnp.float32) * gate_k[..., None], axis=2).astype(x.dtype)

    if m.shared_expert:
        out = out + layers.mlp(params["shared"], x)

    # load-balance + router-z losses (Switch/ST-MoE)
    me = jnp.mean(gates, axis=(0, 1))                           # [E]
    assign = jnp.zeros((E,), jnp.float32).at[idx_k.reshape(-1)].add(1.0) / (G * S * k)
    aux = {
        "load_balance": E * jnp.sum(me * assign),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }
    return out, aux
