"""Mamba-1 selective SSM block (falcon-mamba). Training/prefill run a
*chunked* selective scan: an outer lax.scan over sequence chunks carries the
[B, d_inner, d_state] state, and the chunk interior uses an associative scan —
states for at most one chunk are ever materialized (the full [B,S,d_inner,N]
tensor would be terabytes at 32K).  Decode is a single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.sharding.rules import BATCH, constrain


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, s.d_state, s.d_conv


def init_mamba(key, cfg, dtype):
    d_inner, dt_rank, N, K = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 7)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (d_inner, N))
    return {
        "w_in": layers.init_dense(ks[0], D, 2 * d_inner, dtype),   # x and z branches
        "conv_w": (jax.random.normal(ks[1], (K, d_inner), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_bcdt": layers.init_dense(ks[2], d_inner, dt_rank + 2 * N, dtype),
        "w_dt": layers.init_dense(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -4.0, jnp.float32),        # softplus ~ small dt
        "log_neg_A": jnp.log(A),                                   # A = -exp(log_neg_A)
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": layers.init_dense(ks[4], d_inner, D, dtype),
    }


def _ssm_inputs(params, cfg, xc):
    """xc: [..., d_inner] post-conv. Returns per-step (dA, dBx, C) terms:
    recurrence h = dA * h + dBx, output y = sum_n C*h + D*x."""
    d_inner, dt_rank, N, _ = _dims(cfg)
    bcdt = layers.dense(xc, params["w_bcdt"]).astype(jnp.float32)
    dt_in, B, C = jnp.split(bcdt, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(layers.dense(dt_in.astype(xc.dtype), params["w_dt"])
                         .astype(jnp.float32) + params["dt_bias"])   # [..., d_inner]
    A = -jnp.exp(params["log_neg_A"])                                # [d_inner, N]
    dA = jnp.exp(dt[..., None] * A)                                  # [..., d_inner, N]
    dBx = dt[..., None] * B[..., None, :] * xc.astype(jnp.float32)[..., None]
    return dA, dBx, C


def _chunk_scan(h0, dA, dBx):
    """Within-chunk associative scan. h0: [B,d,N]; dA,dBx: [B,L,d,N]."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    # fold the carried state into the first step
    dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return h                                                        # [B,L,d,N]


def mamba_seq(params, cfg, x, *, return_state: bool = False):
    """x: [B,S,D] -> [B,S,D]; chunked selective scan."""
    d_inner, _, N, K = _dims(cfg)
    B_, S_in, D = x.shape
    chunk = min(cfg.ssm.chunk, S_in)
    pad = (-S_in) % chunk                 # left-pad to a chunk multiple: zero
    if pad:                               # inputs leave the zero state unchanged
        x = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    S = S_in + pad
    xz = layers.dense(x, params["w_in"])
    xs, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv; keep d_inner on the model axis throughout the
    # scan internals (otherwise the [B,S,d_inner,N] state tensors replicate
    # — the falcon_mamba train_4k §Perf-M1 fix)
    xs = constrain(xs, P(BATCH, None, "model"))
    xp = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(xp[:, i: i + S, :] * params["conv_w"][i] for i in range(K))
    xc = jax.nn.silu(xc + params["conv_b"])
    xc = constrain(xc, P(BATCH, None, "model"))
    dA, dBx, C = _ssm_inputs(params, cfg, xc)
    dA = constrain(dA, P(BATCH, None, "model", None))
    dBx = constrain(dBx, P(BATCH, None, "model", None))

    if cfg.use_kernels:
        # Pallas selective-scan: state lives in VMEM across the chunk grid;
        # HBM traffic = one pass over dA/dBx/C + one write of y
        # (the §Perf-M endgame — kernels/selective_scan).
        from repro.kernels.selective_scan.ops import selective_scan
        y, h_last = selective_scan(dA, dBx, C, chunk=chunk)
        y = y.reshape(B_, S, d_inner)
    else:
        nc = S // chunk
        dAc = dA.reshape(B_, nc, chunk, d_inner, N)
        dBxc = dBx.reshape(B_, nc, chunk, d_inner, N)
        Cc = C.reshape(B_, nc, chunk, N)

        def outer(h, xs_):
            dAj, dBxj, Cj = xs_
            h_all = _chunk_scan(h, dAj, dBxj)                       # [B,chunk,d,N]
            h_all = constrain(h_all, P(BATCH, None, "model", None))
            y = jnp.einsum("bldn,bln->bld", h_all, Cj)
            return h_all[:, -1], y

        h0 = jnp.zeros((B_, d_inner, N), jnp.float32)
        h_last, y = jax.lax.scan(outer, h0, (jnp.swapaxes(dAc, 0, 1),
                                             jnp.swapaxes(dBxc, 0, 1),
                                             jnp.swapaxes(Cc, 0, 1)))
        y = jnp.swapaxes(y, 0, 1).reshape(B_, S, d_inner)
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = layers.dense(y, params["w_out"])[:, pad:]
    if not return_state:
        return out
    tail = xs[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
        xs, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"h": h_last, "conv": tail}


def mamba_decode(params, cfg, x, state):
    """x: [B,D]; state {"h": [B,d,N] f32, "conv": [B,K-1,d]}."""
    d_inner, _, N, K = _dims(cfg)
    xz = layers.dense(x, params["w_in"])
    xt, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"], xt[:, None, :]], axis=1)
    xc = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
    xc = jax.nn.silu(xc)
    dA, dBx, C = _ssm_inputs(params, cfg, xc)                       # [B,d,N]x2,[B,N]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, C) + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return layers.dense(y, params["w_out"]), {"h": h, "conv": window[:, 1:, :]}


def init_mamba_cache(cfg, batch: int, dtype):
    d_inner, _, N, K = _dims(cfg)
    return {"h": jnp.zeros((batch, d_inner, N), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, d_inner), dtype)}
