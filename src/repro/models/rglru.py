"""RG-LRU recurrent block (RecurrentGemma / Griffin): conv1d + gated linear
recurrence. Sequence form uses an associative scan (log-depth on TPU);
decode is a single-step state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0          # RG-LRU decay sharpness constant (Griffin)
_MAX_LOG_A = -8.0 # softplus(lambda) init spread


def init_rglru(key, cfg, dtype):
    D = cfg.d_model
    W = cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_x": layers.init_dense(ks[0], D, W, dtype),        # recurrence branch
        "w_gate": layers.init_dense(ks[1], D, W, dtype),     # GeLU gate branch
        "conv_w": (jax.random.normal(ks[2], (4, W), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "w_a": layers.init_dense(ks[3], W, W, dtype),        # recurrence gate r_t
        "w_i": layers.init_dense(ks[4], W, W, dtype),        # input gate i_t
        # Λ parametrized so a = exp(-c·softplus(Λ)·r) starts near 1
        "log_lambda": jnp.linspace(0.3, 0.9, W, dtype=jnp.float32),
        "w_out": layers.init_dense(ks[5], W, D, dtype),
    }


def _gates(params, xw):
    """xw: [..., W] post-conv activations -> (a, bx) of the recurrence
    h = a * h_prev + bx with b = sqrt(1-a^2) * i_t * x."""
    r = jax.nn.sigmoid(layers.dense(xw, params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.dense(xw, params["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["log_lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, b * i * xw.astype(jnp.float32)


def _conv_seq(params, x):
    """Causal depthwise conv1d (k=4) over [B,S,W]."""
    k = params["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * params["conv_w"][i] for i in range(k))
    return out + params["conv_b"]


def rglru_seq(params, cfg, x, *, return_state: bool = False):
    """x: [B,S,D] -> [B,S,D]; full-sequence recurrent block."""
    gate = jax.nn.gelu(layers.dense(x, params["w_gate"]))
    xt = layers.dense(x, params["w_x"])
    xw = _conv_seq(params, xt)
    a, bx = _gates(params, xw)                                # [B,S,W] f32
    # first-order linear recurrence via associative scan over seq axis
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    out = layers.dense(h.astype(x.dtype) * gate, params["w_out"])
    if not return_state:
        return out
    k = params["conv_w"].shape[0]
    tail = xt[:, -(k - 1):, :] if x.shape[1] >= k - 1 else jnp.pad(
        xt, ((0, 0), (k - 1 - x.shape[1], 0), (0, 0)))
    return out, {"h": h[:, -1], "conv": tail}


def rglru_decode(params, cfg, x, state):
    """x: [B,D]; state {"h": [B,W] f32, "conv": [B,k-1,W]} -> (out, state)."""
    gate = jax.nn.gelu(layers.dense(x, params["w_gate"]))
    xt = layers.dense(x, params["w_x"])                        # [B,W]
    window = jnp.concatenate([state["conv"], xt[:, None, :]], axis=1)  # [B,k,W]
    xw = jnp.einsum("bkw,kw->bw", window, params["conv_w"]) + params["conv_b"]
    a, bx = _gates(params, xw)
    h = a * state["h"] + bx
    out = layers.dense(h.astype(x.dtype) * gate, params["w_out"])
    return out, {"h": h, "conv": window[:, 1:, :]}


def init_rglru_cache(cfg, batch: int, dtype):
    W = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, 3, W), dtype)}
