"""Sharding rules: param-path -> PartitionSpec, plus input/cache specs.

Policy (DESIGN.md §5):
 - batch dims ride ("pod","data") (pod axis present only on the multi-pod mesh);
 - TP: head/ff/expert/vocab dims ride "model";
 - FSDP: the complementary big dim of each weight rides "data";
 - an axis is applied only if the dim is divisible by its mesh extent
   (best-effort rule — e.g. smollm's 15 heads stay unsharded on a 16-way TP).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def constrain(x, spec: P):
    """Best-effort with_sharding_constraint: no-op outside a mesh context,
    and silently drops mesh axes that are absent or don't divide the dim
    (e.g. a 15-head tensor on a 16-way model axis stays unsharded)."""
    mesh = compat.get_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    entries = [_fit(e, x.shape[i], mesh) for i, e in enumerate(spec)]
    entries += [None] * (x.ndim - len(entries))
    return jax.lax.with_sharding_constraint(x, P(*entries))


BATCH = ("pod", "data")   # generic batch-dim axes (pod dropped on single-pod)

# decode caches with S >= this are sequence-sharded over `model` (shard_map
# partial-softmax decode); smaller caches (local windows, tests) stay
# batch-sharded. MUST stay in sync between cache_specs and the decode paths.
SEQ_SHARD_MIN_S = 8192


def seq_shardable(S: int, mesh) -> bool:
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    return ("model" in names and S % mesh.shape["model"] == 0
            and S >= SEQ_SHARD_MIN_S)


def _fit(spec_entry, dim: int, mesh: Mesh):
    """Drop mesh axes that don't divide `dim`."""
    if spec_entry is None:
        return None
    axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    kept = []
    size = 1
    for a in axes:
        if a not in mesh.axis_names:
            continue
        ext = mesh.shape[a]
        if dim % (size * ext) == 0:
            kept.append(a)
            size *= ext
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


# path regex -> raw spec (per trailing dims; leading stacked dims get None)
_RULES = [
    (r"embed$",                    ("model", None)),            # [V, D]
    (r"frontend/proj$",            ("data", "model")),
    (r"(w_q|w_uq)$",               ("data", "model")),
    (r"(w_k|w_v)$",                ("data", "model")),
    (r"w_o$",                      ("model", "data")),
    (r"(w_dq|w_dkv)$",             ("data", "model")),
    (r"(w_uk|w_uv)$",              (None, "model")),            # [kv_lora, H*hd]
    (r"(w_gate|w_up|w_in|w_x)$",   ("data", "model")),          # [D, F]
    (r"(w_down|w_out)$",           ("model", "data")),          # [F, D]
    (r"router$",                   ("data", None)),
    (r"ffn/w_gate$",               ("model", "data", None)),    # MoE [E, D, F] (EP)
    (r"ffn/w_up$",                 ("model", "data", None)),
    (r"ffn/w_down$",               ("model", None, "data")),
    (r"w_bcdt$",                   ("model", None)),            # [d_inner, ...]
    (r"w_dt$",                     (None, "model")),
    (r"(conv_w|conv_b|dt_bias|D)$", (None,)),
    (r"log_neg_A$",                ("model", None)),
    (r"(w_a|w_i)$",                ("model", None)),            # lru [W, W]
    (r"(norm|scale|bias|log_lambda|q_norm|k_norm|kv_norm)", (None,)),
]
# NOTE: order matters — first match wins; MoE expert weights are matched by
# the `ffn/...` entries *before* the generic w_gate/w_down rules because the
# generic rules assume 2-D weights; see _spec_for.


def _spec_for(path: str, ndim: int, mesh: Mesh, shape) -> P:
    raw: tuple | None = None
    # 3-D (stacked-expert) weights need the MoE rules; check those first.
    for pat, spec in _RULES:
        if pat.startswith("ffn/") and re.search(pat, path) and ndim - _lead(path) == 3:
            raw = spec
            break
    if raw is None:
        for pat, spec in _RULES:
            if re.search(pat, path):
                raw = spec
                break
    if raw is None:
        raw = (None,) * ndim
    lead = ndim - len(raw)
    if lead < 0:          # param has fewer dims than rule (e.g. reduced cfg)
        raw = raw[-ndim:]
        lead = 0
    entries = [None] * lead + [
        _fit(s, shape[lead + i], mesh) for i, s in enumerate(raw)]
    return P(*entries)


def _lead(path: str) -> int:
    # stacked group params have 1 leading layer dim
    return 1 if "/groups/" in path or path.startswith("groups/") else 0


def _path_str(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params, mesh: Mesh, *, profile: str = "train"):
    """PartitionSpec pytree matching `params`.

    profile="train": TP over `model` + FSDP over `data` (weights gathered
    per layer, reduce-scattered grads) — the memory-optimal training layout.
    profile="serve": TP/EP only — weights replicated across `data`; decoding
    must NOT re-gather FSDP shards every token (§Perf iteration S1)."""
    def one(kp, leaf):
        path = _path_str(kp)
        nd, shape = np.ndim(leaf), np.shape(leaf)
        if profile == "serve" and re.search(r"ffn/(w_gate|w_up|w_down)$", path) \
                and nd - _lead(path) == 3:
            # serving MoE layout: EP over `data`, intra-expert TP over
            # `model` — every expert shard lives on exactly one device row,
            # nothing is re-gathered per decode step.
            lead = [None] * _lead(path)
            if path.endswith("w_down"):       # [L, E, F, D]
                return P(*lead, _fit("data", shape[-3], mesh),
                         _fit("model", shape[-2], mesh), None)
            return P(*lead, _fit("data", shape[-3], mesh), None,
                     _fit("model", shape[-1], mesh))
        spec = _spec_for(path, nd, mesh, shape)
        if profile == "serve":
            spec = P(*[_strip_data(e) for e in spec])
        return spec
    return jax.tree_util.tree_map_with_path(one, params)


def _strip_data(entry):
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    kept = tuple(a for a in axes if a not in ("data", "pod"))
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def param_shardings(params, mesh: Mesh, *, profile: str = "train"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, profile=profile))


def opt_state_specs(opt_state, mesh: Mesh):
    """Optimizer-state specs: moments shard like their params (path-based
    rules still match since state paths embed the param name); adafactor's
    factored stats drop the reduced dim's entry; `count` is replicated."""
    def one(kp, leaf):
        path = _path_str(kp)
        nd = np.ndim(leaf)
        if path.endswith("count"):
            return P()
        if path.endswith("/vr"):          # mean over last dim of the param
            s = _spec_for(path[:-3], nd + 1, mesh, np.shape(leaf) + (10 ** 9,))
            return P(*s[:-1])
        if path.endswith("/vc"):          # mean over second-to-last dim
            shape = np.shape(leaf)
            fake = shape[:-1] + (10 ** 9,) + shape[-1:]
            s = _spec_for(path[:-3], nd + 1, mesh, fake)
            return P(*(s[:-2] + s[-1:]))
        return _spec_for(path, nd, mesh, np.shape(leaf))
    return jax.tree_util.tree_map_with_path(one, opt_state)


# ----------------------------------------------------------- activations/io
def data_spec(mesh: Mesh, ndim: int) -> P:
    """[B, ...] batch-sharded."""
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


def cache_specs(cache, mesh: Mesh):
    """KV/state caches: batch dim sharded over (pod,data) — best-effort (the
    long_500k cell has B=1 and falls back toward replication); for attention
    KV [n,B,S,K,hd] the kv-head dim rides model when divisible; MLA latent
    [n,B,S,latent] is batch-only (no head dim — the paper's scenario)."""
    b = batch_axes(mesh)

    def one(kp, leaf):
        nd = np.ndim(leaf)
        shape = np.shape(leaf)
        bfit = _fit(b, shape[1], mesh) if nd >= 2 else None
        if nd == 5:       # [n, B, S, K, hd]
            # big full-attention caches are S-sharded over model (matches
            # core.etap.seq_sharded_gqa_decode); small (window) caches are
            # batch-sharded only.
            s = "model" if seq_shardable(shape[2], mesh) else None
            return P(None, bfit, s, None, None)
        if nd == 4:       # [n, B, S, latent] or [n, B, d_inner, N]
            path = _path_str(kp)
            if path.endswith("h"):           # mamba state [n,B,d_inner,N]
                d = _fit("model", shape[2], mesh)
                return P(None, bfit, d, None)
            # MLA latent cache: S-sharded over model (no head dim exists);
            # matches core.etap.seq_sharded_decode's in_specs.
            s = "model" if seq_shardable(shape[2], mesh) else None
            return P(None, bfit, s, None)
        if nd == 3:       # [n, B, W] / [n, B, k-1(, ...)]
            d = _fit("model", shape[2], mesh)
            return P(None, bfit, d)
        return P(*([None] * nd))
    return jax.tree_util.tree_map_with_path(one, cache)
