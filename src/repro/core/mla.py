"""Multi-head Latent Attention (DeepSeek) with ETAP decode.

Training / prefill use the "naive" (decompressed) form. Decode uses the
*absorbed* form FlashMLA targets: the per-head up-projections W_uk / W_uv are
folded into the query and output, so attention runs over the shared 576-d
latent cache  c = [rmsnorm(c_kv) ; rope(k_r)]  — a single [B,S,576] stream
serving both K and V (V = c[..., :kv_lora_rank]).  This is the exact
16-heads-vs-huge-context GEMM the paper transposes with ETAP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import attn_spec
from repro.core.etap import (decode_attention, decode_attention_paged,
                             prefill_attention_paged, seq_sharded_decode,
                             verify_attention_paged)
from repro.models import layers
from repro.models.attention import causal_attention
from repro.runtime import paged_cache


def init_mla(key, cfg, dtype):
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 7)
    return {
        "w_dq": layers.init_dense(ks[0], D, m.q_lora_rank, dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "w_uq": layers.init_dense(ks[1], m.q_lora_rank, H * m.qk_head_dim, dtype),
        # fused down-projection: [kv_lora | rope] columns
        "w_dkv": layers.init_dense(ks[2], D, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "w_uk": layers.init_dense(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype),
        "w_uv": layers.init_dense(ks[4], m.kv_lora_rank, H * m.v_head_dim, dtype),
        "w_o": layers.init_dense(ks[5], H * m.v_head_dim, D, dtype),
    }


def _queries(params, cfg, x, positions):
    """x: [..., D] -> (q_nope [..., H, nope], q_rope [..., H, rope])."""
    m, H = cfg.mla, cfg.num_heads
    cq = layers.rms_norm(layers.dense(x, params["w_dq"]), params["q_norm"], cfg.norm_eps)
    q = layers.dense(cq, params["w_uq"]).reshape(*x.shape[:-1], H, m.qk_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(params, cfg, x, positions):
    """x: [..., D] -> latent cache rows [..., kv_lora+rope] (c in the paper)."""
    m = cfg.mla
    dkv = layers.dense(x, params["w_dkv"])
    c_kv = layers.rms_norm(dkv[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = layers.apply_rope(dkv[..., m.kv_lora_rank:], positions, cfg.rope_theta)
    return jnp.concatenate([c_kv, k_rope], axis=-1)


def mla_train(params, cfg, x, positions, *, return_cache: bool = False):
    """Naive (decompressed) MLA for training/prefill. x: [B,S,D] -> [B,S,D]."""
    m, H = cfg.mla, cfg.num_heads
    B, S, D = x.shape
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c = _latent(params, cfg, x, positions)                    # [B,S,kv+rope]
    c_kv, k_rope = c[..., : m.kv_lora_rank], c[..., m.kv_lora_rank:]
    k_nope = layers.dense(c_kv, params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = layers.dense(c_kv, params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))], axis=-1)
    scale = m.qk_head_dim ** -0.5
    o = causal_attention(q, k, v, scale=scale)                # kv heads == H
    out = layers.dense(o.reshape(B, S, H * m.v_head_dim), params["w_o"])
    if return_cache:
        return out, {"c": c}
    return out


def _absorbed_query(params, cfg, x, positions):
    """Absorbed-form decode query:
    q_c[b,h] = q_nope[b,h] · W_uk[:,h]  (512-d), q = [q_c ; q_rope] (576-d).
    x: [B,D]; positions: [B,1]. Returns q: [B,H,latent]."""
    m, H = cfg.mla, cfg.num_heads
    q_nope, q_rope = _queries(params, cfg, x[:, None, :], positions)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]               # [B,H,*]
    # absorb W_uk into the query: [B,H,nope] x [kv,H,nope] -> [B,H,kv]
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_c = jnp.einsum("bhd,chd->bhc", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32)).astype(x.dtype)
    return jnp.concatenate([q_c, q_rope], axis=-1)            # [B,H,latent]


def _absorbed_out(params, cfg, o_lat, dtype):
    """Fold W_uv into the latent attention output and project:
    o[b,h] = o_latent[b,h] · W_uvᵀ → W_o. o_lat: [B,H,kv]. Returns [B,D]."""
    m, H = cfg.mla, cfg.num_heads
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bhc,chd->bhd", o_lat.astype(jnp.float32),
                   w_uv.astype(jnp.float32)).astype(dtype)
    return layers.dense(o.reshape(o.shape[0], -1), params["w_o"])


def mla_decode(params, cfg, x, cache, pos, *, spec=None, **legacy):
    """Absorbed-form decode. x: [B,D]; cache: {"c": [B,Smax,latent]}.
    spec.kv_splits: split-KV count for the decode kernel (None = auto);
    the per-layer scale and cfg.use_kernels are folded into the spec here.

    scores   = q · cᵀ  — via ETAP as  c · qᵀ  with the context on M.
    o_latent = P · c[..., :512]; see :func:`_absorbed_query`/`_absorbed_out`.
    """
    spec = attn_spec.coerce(spec, legacy, where="mla_decode")
    m = cfg.mla
    B, D = x.shape
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = _absorbed_query(params, cfg, x, positions)
    c_t = _latent(params, cfg, x[:, None, :], positions)[:, 0]  # [B,latent]
    scale = m.qk_head_dim ** -0.5
    from repro.sharding.rules import seq_shardable
    mesh = compat.get_mesh()
    seq_shard = seq_shardable(cache["c"].shape[1], mesh)
    if seq_shard:
        # latent cache is S-sharded over the model axis (no head dim to
        # shard — the paper's single-instance scenario); flash-decode-style
        # cross-shard softmax combine. See core.etap.seq_sharded_decode.
        o_lat, cache_c = seq_sharded_decode(
            q, cache["c"], c_t, pos, dv=m.kv_lora_rank, scale=scale)
    else:
        cache_c = jax.lax.dynamic_update_index_in_dim(cache["c"], c_t, pos, 1)
        length = jnp.full((B,), pos + 1, jnp.int32)
        # Single latent stream: K is the full 576 latent, V its first 512 cols.
        o_lat = decode_attention(
            q, cache_c, cache_c[..., : m.kv_lora_rank], length,
            spec=spec.replace(scale=scale,
                              use_kernels=cfg.use_kernels))    # [B,H,512]
    return _absorbed_out(params, cfg, o_lat, x.dtype), {"c": cache_c}


def mla_decode_paged(params, cfg, x, cache, table, lengths, *,
                     spec=None, **legacy):
    """Absorbed-form decode against a PAGED latent cache.

    x: [B,D]; cache: {"c": pool [num_blocks, page, latent]}; table:
    [B,max_blocks]; lengths: [B] — each sequence's new token is written at
    its own position `lengths[b]` (continuous batching serves ragged
    lengths, so there is no shared scalar `pos`).  The single 576-wide
    latent pool is streamed once through the block table; V is its first
    kv_lora_rank columns (same one-stream argument as the dense MLA path).
    Returns (out [B,D], {"c": updated pool})."""
    spec = attn_spec.coerce(spec, legacy, where="mla_decode_paged")
    m = cfg.mla
    B, D = x.shape
    positions = lengths[:, None].astype(jnp.int32)            # [B,1]
    q = _absorbed_query(params, cfg, x, positions)
    c_t = _latent(params, cfg, x[:, None, :], positions)[:, 0]  # [B,latent]
    inner = spec.replace(scale=m.qk_head_dim ** -0.5,
                         use_kernels=cfg.use_kernels)
    if "c_sz" in cache:        # quantized layout: codes + (scale, zp) pools
        pool, sz = paged_cache.append_rows_quant(
            cache["c"], cache["c_sz"], table, lengths, c_t)
        o_lat = decode_attention_paged(
            q, pool, None, table, lengths + 1, spec=inner,
            dv=m.kv_lora_rank, k_sz=sz)
        return (_absorbed_out(params, cfg, o_lat, x.dtype),
                {"c": pool, "c_sz": sz})
    pool = paged_cache.append_rows(cache["c"], table, lengths, c_t)
    o_lat = decode_attention_paged(
        q, pool, None, table, lengths + 1, spec=inner,
        dv=m.kv_lora_rank)                                    # [B,H,512]
    return _absorbed_out(params, cfg, o_lat, x.dtype), {"c": pool}


def _mla_chunk(params, cfg, x, cache, table, lengths, positions, *, spec,
               qpos=None):
    """Shared body of chunked prefill and draft verification: append the
    chunk's latent rows through the table, then run absorbed-form attention
    over pool positions <= each query row's own horizon.  ``positions``
    [B,C] drives rope AND (via qpos) the causal mask; qpos None → the
    prefill entry (horizon = start + row index, implied by the kernel),
    else the explicit per-row horizon of the verify entry."""
    m, H = cfg.mla, cfg.num_heads
    B, C, D = x.shape
    q_nope, q_rope = _queries(params, cfg, x, positions)      # [B,C,H,*]
    # absorb W_uk into the chunk queries: [B,C,H,nope] x [kv,H,nope]
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_c = jnp.einsum("bchd,khd->bchk", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32)).astype(x.dtype)
    q = jnp.concatenate([q_c, q_rope], axis=-1)               # [B,C,H,latent]
    c_rows = _latent(params, cfg, x, positions)               # [B,C,latent]
    inner = spec.replace(scale=m.qk_head_dim ** -0.5,
                         use_kernels=cfg.use_kernels)
    if "c_sz" in cache:        # quantized layout: codes + (scale, zp) pools
        pool, sz = paged_cache.append_chunk_quant(
            cache["c"], cache["c_sz"], table, lengths, c_rows)
        kw = dict(spec=inner, dv=m.kv_lora_rank, k_sz=sz)
        new_cache = {"c": pool, "c_sz": sz}
    else:
        pool = paged_cache.append_chunk(cache["c"], table, lengths, c_rows)
        kw = dict(spec=inner, dv=m.kv_lora_rank)
        new_cache = {"c": pool}
    if qpos is None:
        o_lat = prefill_attention_paged(q, pool, None, table, lengths, **kw)
    else:
        o_lat = verify_attention_paged(q, pool, None, table, lengths, qpos,
                                       **kw)                  # [B,C,H,kv]
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bchk,khd->bchd", o_lat.astype(jnp.float32),
                   w_uv.astype(jnp.float32)).astype(x.dtype)
    out = layers.dense(o.reshape(B, C, H * m.v_head_dim), params["w_o"])
    return out, new_cache


def mla_prefill_chunk(params, cfg, x, cache, table, lengths, *,
                      spec=None, **legacy):
    """Absorbed-form CHUNKED prefill against a paged latent cache
    (DESIGN.md §9).

    x: [B,C,D] — C prompt tokens per sequence at absolute positions
    lengths[b] + c; cache: {"c": pool}; table: [B,max_blocks]; lengths: [B]
    tokens already written (the chunk start).  The chunk's latent rows are
    appended into the pool FIRST, then attention runs over pool positions
    <= each query's own position — causal inside the chunk, full over the
    previously-written context.  Mathematically this is the single-shot
    naive prefill: q·k = [q_nope·W_uk ; q_rope]·[c_kv ; k_rope] and
    o = P·(W_uv c_kv) = (P·c_kv)·W_uv, so scores and outputs agree with
    mla_train to float noise while streaming the 576-wide latent once.
    Returns (out [B,C,D], {"c": updated pool})."""
    spec = attn_spec.coerce(spec, legacy, where="mla_prefill_chunk")
    C = x.shape[1]
    positions = lengths[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    return _mla_chunk(params, cfg, x, cache, table, lengths, positions,
                      spec=spec)


def mla_verify_chunk(params, cfg, x, cache, table, lengths, qpos, *,
                     spec=None, **legacy):
    """Absorbed-form DRAFT VERIFICATION against the paged latent cache
    (DESIGN.md §14): score k draft rows in one chunked-prefill-shaped pass.

    x: [B,k,D] — the draft tokens' embeddings; qpos: [B,k] each draft
    row's absolute position (a linear chain is lengths[:, None] +
    arange(k), which makes this bitwise identical to
    :func:`mla_prefill_chunk`).  The draft latent rows are appended into
    the pool at lengths — the in-cache half of in-cache verification; the
    scheduler rewinds rejected rows afterwards with BlockPool.truncate,
    never a pool rewrite.  Returns (out [B,k,D], updated cache)."""
    spec = attn_spec.coerce(spec, legacy, where="mla_verify_chunk")
    qpos = qpos.astype(jnp.int32)
    return _mla_chunk(params, cfg, x, cache, table, lengths, qpos,
                      spec=spec, qpos=qpos)


def init_mla_cache(cfg, batch: int, max_len: int, dtype):
    return {"c": jnp.zeros((batch, max_len, cfg.mla.latent_dim), dtype)}


def init_mla_cache_paged(cfg, layout, dtype, kv_dtype: str = "fp"):
    """Paged latent pool (block 0 = reserved null block, see
    runtime/paged_cache.py).  kv_dtype "int8"/"fp8": the pool stores codes
    and a parallel per-row (scale, zp) pool rides under "c_sz"
    (DESIGN.md §11); scale 1 / zp 0 makes the all-zero init round-trip
    exactly."""
    shape = (layout.num_blocks, layout.block_size, cfg.mla.latent_dim)
    qdt = paged_cache.quant_dtype(kv_dtype)
    if qdt is None:
        return {"c": jnp.zeros(shape, dtype)}
    sz0 = jnp.concatenate(
        [jnp.ones(shape[:2] + (1,), jnp.float32),        # scale
         jnp.zeros(shape[:2] + (1,), jnp.float32)], -1)  # zero-point
    return {"c": jnp.zeros(shape, qdt), "c_sz": sz0}


def mla_prefill_cache(params, cfg, x, positions):
    """Latent cache rows for a whole prompt (used by prefill)."""
    return _latent(params, cfg, x, positions)
