"""One frozen spec for every attention entry point (DESIGN.md §14).

Before this module every attention entry threaded six parallel keywords
(``mode``, ``rescale``, ``kv_splits``, ``kv_dtype``, ``block``, ``scale``)
by hand, and speculative decoding adds two more (``spec_tokens``,
``spec_draft``).  :class:`AttnSpec` packs them into ONE frozen, hashable
dataclass that rides the jit cache as a single static argument.

Three invariants make the spec safe as a static jit key:

  1. **Resolution before the cache** — ``rescale=None`` (the process
     default) is resolved to a concrete mode string BEFORE the jitted
     function is looked up (:func:`canonicalize`), so flipping
     ``softmax_state.set_default_mode`` can never serve a stale trace.
     This preserves the contract ``jit_with_rescale`` established.
  2. **Projection onto the entry's used fields** — every entry declares
     which spec fields its trace depends on (``uses``); all other fields
     are canonicalized to their defaults before keying the cache, so
     flipping an unused field (say ``spec_tokens`` on a decode kernel)
     never retraces (tests/test_softmax_state.py pins this).
  3. **Keyword shims** — the legacy keyword signature still works: the
     entry wrapper collects spec-field keywords, builds an
     :class:`AttnSpec`, and emits a ``DeprecationWarning``.  Passing both
     ``spec=`` and a legacy keyword is an error, never a silent merge.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings

import jax

from repro.kernels import softmax_state
from repro.runtime import telemetry


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Frozen, jit-hashable attention configuration.

    ``scale`` is the softmax temperature (required in practice at kernel
    entries; the 0.0 default exists so model-level specs can be built
    before the per-layer scale is known).  ``rescale=None`` means "the
    process default" and is resolved before any jit lookup.
    ``kv_splits=None`` means auto-scheduled; the legacy ``n_splits``
    keyword aliases onto it.  ``spec_tokens``/``spec_draft`` configure
    speculative decoding (0 = off) and are consumed by the serve loop and
    ``model.verify_step`` — no kernel trace depends on them."""
    scale: float = 0.0
    mode: str = "etap"             # attention pipeline: etap | standard
    rescale: str | None = None     # online-softmax mode (None = default)
    kv_splits: int | None = None   # split-KV count (None = auto)
    kv_dtype: str = "fp"           # paged pool storage layout
    block: int = 512               # dense KV block size
    use_kernels: bool = False      # dispatch to the Pallas kernels
    interpret: bool = True         # Pallas interpret mode (CPU)
    spec_tokens: int = 0           # speculative draft length k (0 = off)
    spec_draft: str = "ngram"      # draft proposer: ngram | head

    def replace(self, **kw) -> "AttnSpec":
        return dataclasses.replace(self, **kw)


FIELDS = tuple(f.name for f in dataclasses.fields(AttnSpec))
_DEFAULTS = AttnSpec()
# legacy keyword spellings that map onto a differently-named spec field
LEGACY_ALIASES = {"n_splits": "kv_splits"}
LEGACY_KEYS = frozenset(FIELDS) | frozenset(LEGACY_ALIASES)


def coerce(spec: AttnSpec | None, legacy: dict, *,
           where: str = "attention entry") -> AttnSpec:
    """Build the effective spec from ``spec=`` or legacy keywords.

    ``legacy`` holds spec-field keywords collected from a call site that
    predates the spec API; a non-empty dict emits ``DeprecationWarning``
    and builds a fresh :class:`AttnSpec`.  Mixing both styles raises:
    silently merging a keyword into a caller-built spec would hide which
    one wins."""
    if legacy:
        if spec is not None:
            raise TypeError(
                f"{where}: got both spec= and legacy attention keyword(s) "
                f"{sorted(legacy)}; fold them into the AttnSpec")
        warnings.warn(
            f"{where}: attention keyword(s) {sorted(legacy)} are "
            f"deprecated; pass spec=AttnSpec(...) instead",
            DeprecationWarning, stacklevel=3)
        kw = {LEGACY_ALIASES.get(k, k): v for k, v in legacy.items()}
        return AttnSpec(**kw)
    return spec if spec is not None else AttnSpec()


def split_legacy(kw: dict) -> dict:
    """Pop every spec-field keyword out of ``kw`` (mutated in place) and
    return them — the shim half of an entry wrapper."""
    return {k: kw.pop(k) for k in list(kw) if k in LEGACY_KEYS}


def project(spec: AttnSpec, uses) -> AttnSpec:
    """Canonicalize every field OUTSIDE ``uses`` to its default.

    The projected spec is what keys the jit cache: two specs differing
    only in fields an entry's trace ignores collapse to one cache entry,
    so flipping an unused knob never retraces (the stale-flip regression
    test).  ``scale`` is always kept."""
    keep = set(uses) | {"scale"}
    return AttnSpec(**{f: getattr(spec if f in keep else _DEFAULTS, f)
                       for f in FIELDS})


def canonicalize(spec: AttnSpec, uses) -> AttnSpec:
    """Project onto ``uses`` and resolve ``rescale`` to a concrete mode —
    the full pre-jit-cache normalization of an entry wrapper."""
    spec = project(spec, uses)
    return spec.replace(rescale=softmax_state.resolve(spec.rescale))


def _spec_tag(spec: AttnSpec) -> str:
    """Compact spec label for profiler records — the fields that select a
    kernel family, not the full repr."""
    return (f"mode={spec.mode} rescale={spec.rescale} "
            f"kv={spec.kv_dtype} splits={spec.kv_splits}")


def _geometry(args, kw) -> tuple:
    """Hashable (shape, dtype) summary of the array arguments — what the
    profiler aggregates launches by."""
    geo = []
    for a in args:
        shape = getattr(a, "shape", None)
        if shape is not None:
            geo.append((tuple(shape), str(getattr(a, "dtype", "?"))))
    for k in sorted(kw):
        shape = getattr(kw[k], "shape", None)
        if shape is not None:
            geo.append((k, tuple(shape), str(getattr(kw[k], "dtype", "?"))))
    return tuple(geo)


def attn_entry(*, uses=(), static_argnames=()):
    """Decorator for public attention entry points.

    The decorated function must take ``spec`` keyword-only; the wrapper
    accepts either ``spec=AttnSpec(...)`` or the legacy spec-field
    keywords (DeprecationWarning), canonicalizes (projection onto
    ``uses`` + rescale resolution) BEFORE the jit-cache lookup, and calls
    the jitted body with ``spec`` as a static argument.  Non-spec
    keywords (``k_sz``, ``combine``, ...) pass through untouched;
    ``static_argnames`` lists the non-spec statics among them.

    This wrapper is also the kernel-profiling choke point: when a
    :class:`repro.runtime.telemetry.KernelProfiler` is installed
    (``--profile-kernels``), sampled launches run under
    ``block_until_ready`` and are recorded with the spec tag + argument
    geometry.  Profiling only engages OUTSIDE other traces — if any
    argument is a tracer the entry is being inlined into an enclosing
    jit, where wall-timing is meaningless and ``block_until_ready``
    invalid — and never changes the computation (same jitted call either
    way; forcing completion is a scheduling effect only)."""
    def deco(fn):
        jfn = jax.jit(fn, static_argnames=("spec",) + tuple(static_argnames))

        @functools.wraps(fn)
        def wrapper(*args, spec=None, **kw):
            legacy = split_legacy(kw)
            s = coerce(spec, legacy, where=fn.__name__)
            s = canonicalize(s, uses)
            prof = telemetry.profiler()
            if (prof is not None
                    and not any(isinstance(a, jax.core.Tracer) for a in args)
                    and prof.want()):
                t0 = time.perf_counter()
                out = jfn(*args, spec=s, **kw)
                jax.block_until_ready(out)
                prof.record(fn.__name__, _spec_tag(s), _geometry(args, kw),
                            time.perf_counter() - t0)
                return out
            return jfn(*args, spec=s, **kw)

        wrapper.__wrapped_jit__ = jfn
        wrapper.__attn_uses__ = ("scale",) + tuple(uses)
        return wrapper
    return deco
