"""Efficient Transpose Attention Pipeline (ETAP) — the paper's contribution.

Decode attention computes, per (batch, kv-group):
    standard:  S  = Q Kᵀ,  P = softmax_rows(S),  O = P V          (thin M = heads)
    ETAP:      Sᵀ = K Qᵀ,  Pᵀ = softmax_cols(Sᵀ), Oᵀ = Vᵀ Pᵀ,  O = (Oᵀ)ᵀ
with the online-softmax recurrence carried per *column* of the transposed block
(paper Algorithm 1).  The KV context length rides the M-dimension of every GEMM
in the hot loop, so the thin head dimension never pads the systolic array's
M side, and the score/probability tiles keep S on sublanes end-to-end (see
DESIGN.md §2 for the TPU adaptation of the WGMMA argument).

This module is the *XLA* implementation (lax.scan over KV blocks) used by the
dry-run and as a mid-level reference; ``repro.kernels.etap`` is the Pallas TPU
kernel with the same math, and ``repro.kernels.etap.ref`` is the direct oracle.

Shapes (grouped-query form — MLA is the special case group_size=H, kv "heads"=1):
    q:  [BG, H, Dk]     BG = batch * kv_heads, H = q heads per kv head
    k:  [BG, S, Dk]
    v:  [BG, S, Dv]
    length: [BG] valid cache length per row (mask positions >= length)
returns O: [BG, H, Dv]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import attn_spec
from repro.kernels import softmax_state

NEG_INF = softmax_state.NEG_INF


def _blocks(s: int, block: int) -> int:
    assert s % block == 0, f"S={s} not divisible by block={block}"
    return s // block


def etap_decode_xla(q, k, v, length=None, *, scale: float, block: int = 512,
                    rescale: str | None = None):
    """ETAP transposed decode attention, online softmax over KV blocks.

    Blocks are taken with lax.dynamic_slice inside a fori_loop (not scan xs),
    so the KV cache is streamed in place — no [nb, ...] transpose copy of the
    whole cache per decode step (that copy would double the memory roofline
    term of the paper's core workload)."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    block = min(block, S)
    nb = _blocks(S, block)
    mode = softmax_state.resolve(rescale)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)

    qT = jnp.swapaxes(q, 1, 2).astype(jnp.float32)            # [BG, Dk, H]

    def step(j, carry):
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        # Sᵀ = K·Qᵀ : [BG, block, H] — KV block length on the M dimension.
        sT = jnp.einsum("bkd,bdh->bkh", kj, qT.astype(k.dtype),
                        preferred_element_type=jnp.float32) * scale
        pos = j * block + jnp.arange(block, dtype=jnp.int32)  # [block]
        valid = pos[None, :] < length[:, None]                # [BG, block]
        sT = jnp.where(valid[:, :, None], sT, NEG_INF)
        # column-wise (per-head) stats; Oᵀ += Vᵀ·Pᵀ over the long KV axis.
        return softmax_state.update(
            carry, sT,
            lambda pT: jnp.einsum("bkv,bkh->bvh", vj, pT.astype(v.dtype),
                                  preferred_element_type=jnp.float32),
            axis=1, mode=mode, expand=lambda c: c[:, None, :])

    state = jax.lax.fori_loop(
        0, nb, step, softmax_state.init((BG, H), (BG, Dv, H)))
    oT = softmax_state.finalize(state, expand=lambda l: l[:, None, :])
    return jnp.swapaxes(oT, 1, 2).astype(v.dtype)             # final O = (Oᵀ)ᵀ


def standard_decode_xla(q, k, v, length=None, *, scale: float, block: int = 512,
                        rescale: str | None = None):
    """Baseline (FlashMLA-without-ETAP): untransposed flash decode. Same
    signature/semantics as :func:`etap_decode_xla`; the thin head dim rides M."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    block = min(block, S)
    nb = _blocks(S, block)
    mode = softmax_state.resolve(rescale)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)

    qf = q.astype(jnp.float32)

    def step(j, carry):
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        s = jnp.einsum("bhd,bkd->bhk", qf.astype(k.dtype), kj,
                       preferred_element_type=jnp.float32) * scale
        pos = j * block + jnp.arange(block, dtype=jnp.int32)
        valid = pos[None, :] < length[:, None]                # [BG, block]
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        return softmax_state.update(
            carry, s,
            lambda p: jnp.einsum("bhk,bkv->bhv", p.astype(v.dtype), vj,
                                 preferred_element_type=jnp.float32),
            axis=2, mode=mode, expand=lambda c: c[:, :, None])

    state = jax.lax.fori_loop(
        0, nb, step, softmax_state.init((BG, H), (BG, H, Dv)))
    return softmax_state.finalize(
        state, expand=lambda l: l[:, :, None]).astype(v.dtype)


def etap_partial_xla(q, k, v, length, *, scale: float, block: int = 512,
                     vary_axis=None, rescale: str | None = None):
    """ETAP loop WITHOUT the epilogue: returns raw (m, l, accT) softmax
    statistics — the combinable form used by sequence-sharded decode.
    vary_axis: shard_map manual axis name(s) to mark the carry varying over
    (required when called inside shard_map)."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    block = min(block, S)
    nb = _blocks(S, block)
    mode = softmax_state.resolve(rescale)

    qT = jnp.swapaxes(q, 1, 2).astype(jnp.float32)

    def step(j, carry):
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        sT = jnp.einsum("bkd,bdh->bkh", kj, qT.astype(k.dtype),
                        preferred_element_type=jnp.float32) * scale
        pos = j * block + jnp.arange(block, dtype=jnp.int32)
        valid = pos[None, :] < length[:, None]
        sT = jnp.where(valid[:, :, None], sT, NEG_INF)
        return softmax_state.update(
            carry, sT,
            lambda pT: jnp.einsum("bkv,bkh->bvh", vj, pT.astype(v.dtype),
                                  preferred_element_type=jnp.float32),
            axis=1, mode=mode, expand=lambda c: c[:, None, :])

    init = softmax_state.init((BG, H), (BG, Dv, H))
    if vary_axis is not None:
        init = jax.tree.map(lambda a: compat.pvary(a, vary_axis), init)
    return jax.lax.fori_loop(0, nb, step, init)


def combine_partials(m, l, accT, *, rescale: str | None = None):
    """Merge per-shard (m, l, accT) stats (leading shard axis) into O.
    m,l: [n,BG,H]; accT: [n,BG,Dv,H] -> [BG,H,Dv].  The stat-domain merge
    (and its fp32-on-entry upcast — half-precision exp/sum here would erase
    the split-invariance the combine owes the single-pass path, DESIGN.md
    §6) is :func:`softmax_state.merge_splits`, shared with the Pallas
    combine kernel.  ``rescale`` must match the partials' producer."""
    _, l_g, acc_g = softmax_state.merge_splits(
        m, l, accT, axis=0, mode=softmax_state.resolve(rescale),
        expand=lambda w: w[:, :, None, :])
    oT = acc_g / l_g[:, None, :]                              # [BG,Dv,H]
    return jnp.swapaxes(oT, 1, 2)


def etap_decode_splitkv_xla(q, k, v, length=None, *, scale: float,
                            block: int = 512, n_splits: int = 2,
                            rescale: str | None = None):
    """Two-phase split-KV ETAP decode in pure XLA (DESIGN.md §3).

    The KV context is cut into n_splits contiguous segments; each segment's
    (m, l, accT) partial stats come from a vmapped :func:`etap_partial_xla`
    (XLA parallelizes across segments — the same shape the Pallas phase-1
    kernel gives the TPU grid), merged by :func:`combine_partials`. A fully
    masked segment carries m = NEG_INF and drops out of the merge with
    weight exp(NEG_INF - m*) = 0."""
    BG, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    mode = softmax_state.resolve(rescale)
    if length is None:
        length = jnp.full((BG,), S, jnp.int32)
    if n_splits <= 1:
        return etap_decode_xla(q, k, v, length, scale=scale, block=block,
                               rescale=mode)
    from repro.kernels.etap.schedule import split_geometry
    # effective count: short contexts degrade to fewer non-empty splits
    block, n_splits, npb, padded_s = split_geometry(S, block, n_splits)
    if n_splits <= 1:
        return etap_decode_xla(q, k, v, length, scale=scale, block=block,
                               rescale=mode)
    seg = npb * block
    pad = padded_s - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    ks = jnp.moveaxis(k.reshape(BG, n_splits, seg, Dk), 1, 0)  # [n,BG,seg,Dk]
    vs = jnp.moveaxis(v.reshape(BG, n_splits, seg, Dv), 1, 0)
    starts = jnp.arange(n_splits, dtype=jnp.int32)[:, None] * seg
    seg_len = jnp.clip(length[None, :] - starts, 0, seg)       # [n,BG]
    m, l, accT = jax.vmap(
        lambda kk, vv, ll: etap_partial_xla(q, kk, vv, ll, scale=scale,
                                            block=block,
                                            rescale=mode))(ks, vs, seg_len)
    return combine_partials(m, l, accT, rescale=mode).astype(v.dtype)


def seq_sharded_decode(q, cache, new_row, pos, *, dv: int, scale: float,
                       axis: str = "model", block: int = 512,
                       rescale: str | None = None):
    """Sequence-sharded MLA decode (shard_map over `axis`).

    The MLA latent cache [B, S, L] has NO head dimension, so tensor
    parallelism cannot shard it — instead S is sharded over the model axis;
    each shard (1) writes the new latent row if it owns position `pos`,
    (2) runs the ETAP partial loop over its local S/n slice, and (3) shards
    exchange the tiny (m, l, accT) stats (flash-decode-style cross-device
    softmax combine). q: [B,H,L]; cache: [B,S,L] S-sharded; new_row: [B,L].
    Returns (O [B,H,dv], updated cache)."""
    from jax.sharding import PartitionSpec as P

    mesh = compat.get_mesh()
    mode = softmax_state.resolve(rescale)

    # shard ids ride in as an axis-sharded operand instead of
    # jax.lax.axis_index: the latter lowers to partition-id, which SPMD
    # can't place inside a partially-auto manual region on older JAX.
    shard_ids = jnp.arange(mesh.shape[axis], dtype=jnp.int32)

    def local(q, cache, new_row, pos, sid):
        idx = sid[0]
        S_local = cache.shape[1]
        start = idx * S_local
        slot = jnp.clip(pos - start, 0, S_local - 1)
        owns = (pos >= start) & (pos < start + S_local)
        # single-row conditional write: non-owners rewrite their old row —
        # O(row) traffic, never an O(cache) select copy (§Perf D4)
        old = jax.lax.dynamic_slice_in_dim(cache, slot, 1, axis=1)
        row = jnp.where(owns, new_row[:, None, :], old)
        cache = jax.lax.dynamic_update_slice_in_dim(cache, row, slot, axis=1)
        length = jnp.clip(pos + 1 - start, 0, S_local)
        B = q.shape[0]
        m, l, accT = etap_partial_xla(
            q, cache, cache[..., :dv],
            jnp.full((B,), length, jnp.int32), scale=scale, block=block,
            vary_axis=(axis,), rescale=mode)
        # combine via weighted psum: one all-reduce of [B,dv,H] instead of
        # an n-fold all-gather (§Perf iteration D3 — 8x less wire traffic);
        # the weights come from THE merge definition (softmax_state), the
        # Σ is the all-reduce.
        m_g = jax.lax.pmax(m, axis)                           # [B,H]
        w = softmax_state.merge_weights(m, m_g, mode=mode)
        l_g = jax.lax.psum(l * w, axis)
        acc_g = jax.lax.psum(accT * w[:, None, :], axis)      # [B,dv,H]
        oT = acc_g / l_g[:, None, :]
        return jnp.swapaxes(oT, 1, 2).astype(cache.dtype), cache

    # manual ONLY over the model axis: batch (pod/data) sharding of q/cache
    # keeps propagating automatically outside the manual region.
    return compat.shard_map(
        local, mesh=mesh, axis_names={axis},
        in_specs=(P(), P(None, axis, None), P(), P(), P(axis)),
        out_specs=(P(), P(None, axis, None)),
        check=False,
    )(q, cache, new_row, pos, shard_ids)


def decode_attention(q, k, v, length=None, *, spec=None, **legacy):
    """Unified decode attention entry point, driven by one
    :class:`repro.core.attn_spec.AttnSpec`.

    spec.mode: "etap" (the paper) or "standard" (FlashMLA-like baseline).
    spec.use_kernels: dispatch to the Pallas implementations (tests and
    benchmarks run them with spec.interpret=True on CPU; on a real TPU
    interpret=False).
    spec.kv_splits: KV-split count for the two-phase split-KV pipeline.
    None → auto via kernels.etap.schedule (resolves to 1 at short contexts /
    large batches, i.e. exactly the old single-pass behaviour) on both the
    kernel and XLA "etap" paths; 1 → force single-pass. The "standard" XLA
    loop streams serially regardless — it is the deliberately unsplit
    baseline.
    spec.rescale: softmax-state rescale mode, None → the process default
    (``--rescale`` / REPRO_RESCALE) — resolved here, before any jit cache.
    Legacy keywords (scale=..., mode=..., n_splits=...) shim through
    :func:`attn_spec.coerce` with a DeprecationWarning.
    """
    spec = attn_spec.coerce(spec, legacy, where="decode_attention")
    if spec.use_kernels:
        from repro.kernels.etap import ops as etap_ops
        from repro.kernels.flash_decode import ops as fd_ops
        if spec.mode == "etap":
            return etap_ops.etap_decode_splitkv(q, k, v, length, spec=spec)
        return fd_ops.flash_decode_splitkv(q, k, v, length, spec=spec)
    scale, block = spec.scale, spec.block
    rescale = softmax_state.resolve(spec.rescale)
    n_splits = spec.kv_splits
    if spec.mode == "etap":
        if n_splits is None:
            from repro.kernels.etap.schedule import plan_splits
            n_splits = plan_splits(q.shape[0], k.shape[1], q.shape[1],
                                   v.shape[2], block=block).n_splits
        if n_splits > 1:
            return etap_decode_splitkv_xla(q, k, v, length, scale=scale,
                                           block=block,
                                           n_splits=int(n_splits),
                                           rescale=rescale)
    fn = etap_decode_xla if spec.mode == "etap" else standard_decode_xla
    return fn(q, k, v, length, scale=scale, block=block, rescale=rescale)


# ------------------------------------------------------------------- paged
def _gather_kv(k_pool, v_pool, table, dv: int, k_sz=None, v_sz=None):
    """Materialize the dense (k, v) view of a paged cache: the fallback
    route for paths without a native paged kernel.  v_pool None → MLA-fused
    (V = first `dv` gathered columns).  k_sz/v_sz: per-row (scale, zp)
    pools for quantized code pools (DESIGN.md §11) — the gathered codes
    are dequantized densely here, the XLA twin of the kernels' in-register
    expand (same affine: runtime.paged_cache.dequantize_rows)."""
    from repro.runtime.paged_cache import dequantize_rows, gather_blocks
    k = gather_blocks(k_pool, table)
    if k_sz is not None:
        k = dequantize_rows(k, gather_blocks(k_sz, table))
    if v_pool is not None:
        v = gather_blocks(v_pool, table)
        if v_sz is not None:
            v = dequantize_rows(v, gather_blocks(v_sz, table))
    else:
        v = k[..., :dv]
    return k, v


def etap_decode_paged_xla(q, k_pool, v_pool, table, lengths, *,
                          scale: float, dv: int = 0, k_sz=None, v_sz=None,
                          rescale: str | None = None):
    """Paged ETAP decode in pure XLA: gather the pool rows through the
    block table into the dense layout, then run the blockwise loop with
    block == page — so at block-aligned lengths it is bit-identical to the
    paged Pallas kernel AND to the dense path at equal block size.  XLA
    materializes the gather (one cache-sized copy); the Pallas paged
    kernels avoid it by dereferencing the table inside the grid.
    With v_pool None, V = gathered k_pool[..., :dv] (MLA-fused).
    k_sz/v_sz: (scale, zp) pools for quantized code pools."""
    k, v = _gather_kv(k_pool, v_pool, table, dv, k_sz, v_sz)
    if k_sz is not None:
        q = q.astype(jnp.float32)          # match the dequantized fp32 rows
    return etap_decode_xla(q, k, v, lengths, scale=scale,
                           block=k_pool.shape[1], rescale=rescale)


def decode_attention_paged(q, k_pool, v_pool, table, lengths, *,
                           spec=None, dv: int = 0, k_sz=None, v_sz=None,
                           **legacy):
    """Paged decode attention entry point (the `cache_layout="paged"`
    analogue of :func:`decode_attention`), driven by one AttnSpec.

    q: [B,H,Dk]; pools: [N,page,D*]; table: [B,max_blocks]; lengths: [B].
    v_pool None → MLA-fused (V = first `dv` pool columns, one HBM stream).
    k_sz/v_sz: (scale, zp) pools when the pools hold int8/fp8 codes — the
    kernel path dequants in registers, the XLA path after the gather.
    spec.kv_splits: None = auto via the block-granular paged scheduler; the
    "standard" baseline runs on the gathered dense layout (it exists for
    comparison, not serving)."""
    spec = attn_spec.coerce(spec, legacy, where="decode_attention_paged")
    if spec.use_kernels and spec.mode == "etap":
        from repro.kernels.etap import ops as etap_ops
        if v_pool is None:
            return etap_ops.etap_decode_mla_paged_splitkv(
                q, k_pool, dv, table, lengths, spec=spec, kv_sz=k_sz)
        return etap_ops.etap_decode_paged_splitkv(
            q, k_pool, v_pool, table, lengths, spec=spec,
            k_sz=k_sz, v_sz=v_sz)
    scale = spec.scale
    rescale = softmax_state.resolve(spec.rescale)
    n_splits = spec.kv_splits
    if spec.mode == "etap":
        page = k_pool.shape[1]
        if n_splits is None:
            from repro.kernels.etap.schedule import plan_splits_paged
            n_splits = plan_splits_paged(
                q.shape[0], table.shape[1], page, q.shape[1],
                v_pool.shape[2] if v_pool is not None else dv).n_splits
        if n_splits > 1:
            k, v = _gather_kv(k_pool, v_pool, table, dv, k_sz, v_sz)
            return etap_decode_splitkv_xla(q, k, v, lengths, scale=scale,
                                           block=page,
                                           n_splits=int(n_splits),
                                           rescale=rescale)
        return etap_decode_paged_xla(q, k_pool, v_pool, table, lengths,
                                     scale=scale, dv=dv, k_sz=k_sz,
                                     v_sz=v_sz, rescale=rescale)
    k, v = _gather_kv(k_pool, v_pool, table, dv, k_sz, v_sz)
    if spec.use_kernels:
        from repro.kernels.flash_decode import ops as fd_ops
        return fd_ops.flash_decode_splitkv(
            q, k, v, lengths, spec=spec.replace(block=k_pool.shape[1]))
    return standard_decode_xla(q, k, v, lengths, scale=scale,
                               block=k_pool.shape[1], rescale=rescale)


def etap_prefill_xla(q, k, v, start, *, scale: float, block: int = 512,
                     rescale: str | None = None):
    """Chunked ETAP prefill, online softmax over KV blocks (the XLA twin of
    the paged Pallas prefill kernel — DESIGN.md §9).

    q: [B, Cq, H, Dk] chunk queries at absolute positions start[b] + c;
    k: [B, S, Dk]; v: [B, S, Dv] (the chunk's own rows already written into
    k/v by the caller); start: [B].  The Cq*H query tile rides the N side of
    every GEMM while KV blocks stay on M, with a causal mask per column:
    key position p is live for chunk row c iff p <= start + c.
    Implemented as the linear-chain special case of :func:`etap_verify_xla`
    (qpos = start + row index) — the two are bitwise identical there.
    Returns [B, Cq, H, Dv]."""
    Cq = q.shape[1]
    qpos = start[:, None] + jnp.arange(Cq, dtype=jnp.int32)[None, :]
    return etap_verify_xla(q, k, v, qpos, scale=scale, block=block,
                           rescale=rescale)


def etap_verify_xla(q, k, v, qpos, *, scale: float, block: int = 512,
                    rescale: str | None = None):
    """Draft-verify ETAP attention: the chunked-prefill loop with an
    EXPLICIT per-query-row causal horizon (DESIGN.md §14).

    q: [B, Cq, H, Dk] — the Cq draft rows under verification; qpos: [B, Cq]
    absolute key position row c may attend up to (inclusive; its own pool
    row included).  For a linear draft chain qpos = start[:, None] +
    arange(Cq), which makes this function bit-identical to
    :func:`etap_prefill_xla` — verification IS a chunked prefill.  An
    explicit vector rather than start + row index is the tree hook:
    sibling draft rows share a start but not a mask.
    Returns [B, Cq, H, Dv]."""
    B, Cq, H, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[2]
    CH = Cq * H
    block = min(block, S)
    nb = _blocks(S, block)
    mode = softmax_state.resolve(rescale)

    qT = jnp.swapaxes(q.reshape(B, CH, Dk), 1, 2).astype(jnp.float32)
    # column c*H + h of the transposed score tile is query row c
    qpos = jnp.repeat(qpos.astype(jnp.int32), H, axis=1)       # [B, CH]

    def step(j, carry):
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        sT = jnp.einsum("bkd,bdh->bkh", kj, qT.astype(k.dtype),
                        preferred_element_type=jnp.float32) * scale
        kpos = j * block + jnp.arange(block, dtype=jnp.int32)  # [block]
        valid = kpos[None, :, None] <= qpos[:, None, :]        # [B,block,CH]
        sT = jnp.where(valid, sT, NEG_INF)
        return softmax_state.update(
            carry, sT,
            lambda pT: jnp.einsum("bkv,bkh->bvh", vj, pT.astype(v.dtype),
                                  preferred_element_type=jnp.float32),
            axis=1, mode=mode, expand=lambda c: c[:, None, :])

    state = jax.lax.fori_loop(
        0, nb, step, softmax_state.init((B, CH), (B, Dv, CH)))
    oT = softmax_state.finalize(state, expand=lambda l: l[:, None, :])
    return jnp.swapaxes(oT, 1, 2).reshape(B, Cq, H, Dv).astype(v.dtype)


def prefill_attention_paged(q, k_pool, v_pool, table, start, *, spec=None,
                            dv: int = 0, k_sz=None, v_sz=None, **legacy):
    """Chunked paged prefill attention entry point (the prefill analogue of
    :func:`decode_attention_paged`).

    q: [B,Cq,H,Dk]; pools: [N,page,D*]; table: [B,max_blocks]; start: [B]
    tokens in the pool before the chunk — the chunk's latent/KV rows must
    already be appended (runtime.paged_cache.append_chunk), so the kernels
    stream ONE pool source for both the past context and the live chunk.
    `start` is indifferent to HOW the preceding rows got into the pool:
    written by this request's earlier chunks, or mapped wholesale from a
    prefix-cache hit (DESIGN.md §10) — a prefill that resumes at a nonzero
    offset over donor-computed blocks is the same computation as one that
    resumes over its own, which is why prefix skipping needs no kernel
    changes.  v_pool None → MLA-fused (V = first `dv` pool columns).
    `spec.mode` is accepted for parity with decode but ignored; both modes
    share the transposed loop here — prefill tiles are never thin on M."""
    spec = attn_spec.coerce(spec, legacy, where="prefill_attention_paged")
    if spec.use_kernels:
        from repro.kernels.etap import ops as etap_ops
        if v_pool is None:
            return etap_ops.etap_prefill_mla_paged(
                q, k_pool, dv, table, start, spec=spec, kv_sz=k_sz)
        return etap_ops.etap_prefill_paged(
            q, k_pool, v_pool, table, start, spec=spec,
            k_sz=k_sz, v_sz=v_sz)
    k, v = _gather_kv(k_pool, v_pool, table, dv, k_sz, v_sz)
    if k_sz is not None:
        q = q.astype(jnp.float32)          # match the dequantized fp32 rows
    return etap_prefill_xla(q, k, v, start, scale=spec.scale,
                            block=k_pool.shape[1],
                            rescale=softmax_state.resolve(spec.rescale))


def verify_attention_paged(q, k_pool, v_pool, table, start, qpos, *,
                           spec=None, dv: int = 0, k_sz=None, v_sz=None,
                           **legacy):
    """Speculative-decode verification attention over the paged pool
    (DESIGN.md §14) — the scoring half of draft-then-verify.

    Shaped exactly like :func:`prefill_attention_paged`: the k draft rows
    must already be appended to the pool (append_chunk / append_chunk_quant)
    and `start` [B] is the pre-chunk length, so ONE pool stream covers the
    committed context and the live draft rows.  The only difference is the
    causal mask: the explicit per-row horizon `qpos` [B, Cq] replaces
    start + row index.  A linear chain (qpos = start[:, None] + arange(Cq))
    is bitwise identical to the prefill entry — verification IS a chunked
    prefill — while tree-shaped drafts feed sibling rows with equal start
    but disjoint horizons.  v_pool None → MLA-fused (V = first `dv` pool
    columns); k_sz/v_sz → quantized code pools, dequantized in registers on
    the kernel path and after the gather on the XLA path."""
    spec = attn_spec.coerce(spec, legacy, where="verify_attention_paged")
    if spec.use_kernels:
        from repro.kernels.etap import ops as etap_ops
        if v_pool is None:
            return etap_ops.etap_verify_mla_paged(
                q, k_pool, dv, table, start, qpos, spec=spec, kv_sz=k_sz)
        return etap_ops.etap_verify_paged(
            q, k_pool, v_pool, table, start, qpos, spec=spec,
            k_sz=k_sz, v_sz=v_sz)
    k, v = _gather_kv(k_pool, v_pool, table, dv, k_sz, v_sz)
    if k_sz is not None:
        q = q.astype(jnp.float32)          # match the dequantized fp32 rows
    return etap_verify_xla(q, k, v, qpos, scale=spec.scale,
                           block=k_pool.shape[1],
                           rescale=softmax_state.resolve(spec.rescale))


def gqa_partial_xla(q, k, v, length, *, scale: float, block: int = 512,
                    vary_axis=None, rescale: str | None = None):
    """ETAP partial stats for GQA in the native [B,S,K,hd] cache layout.
    q: [B,K,G,hd]. Returns (m, l, accT): [B,K,G], [B,K,G], [B,K,Dv,G]."""
    B, K, G, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[3]
    block = min(block, S)
    nb = _blocks(S, block)
    mode = softmax_state.resolve(rescale)
    qf = q.astype(jnp.float32)

    def step(j, carry):
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        sT = jnp.einsum("bskd,bkgd->bksg", kj, qf.astype(k.dtype),
                        preferred_element_type=jnp.float32) * scale
        pos = j * block + jnp.arange(block, dtype=jnp.int32)
        valid = pos[None, :] < length[:, None]
        sT = jnp.where(valid[:, None, :, None], sT, NEG_INF)
        return softmax_state.update(
            carry, sT,
            lambda pT: jnp.einsum("bskv,bksg->bkvg", vj, pT.astype(v.dtype),
                                  preferred_element_type=jnp.float32),
            axis=2, mode=mode, expand=lambda c: c[:, :, None, :])

    init = softmax_state.init((B, K, G), (B, K, Dv, G))
    if vary_axis is not None:
        init = jax.tree.map(lambda a: compat.pvary(a, vary_axis), init)
    return jax.lax.fori_loop(0, nb, step, init)


def seq_sharded_gqa_decode(q, k_cache, v_cache, new_k, new_v, pos, *,
                           scale: float, axis: str = "model",
                           block: int = 512, rescale: str | None = None):
    """Sequence-sharded GQA decode (shard_map over `axis`) — the generic-
    attention analogue of :func:`seq_sharded_decode`: each shard owns an
    S/n slice of the [B,S,K,hd] cache, writes the new KV row if `pos` falls
    in its range, runs the ETAP partial loop locally, and shards exchange
    only the (m, l, accT) stats. q: [B,K,G,hd]; new_k/new_v: [B,K,hd].
    Returns (O [B,K*G,Dv], new k_cache, new v_cache)."""
    from jax.sharding import PartitionSpec as P
    mesh = compat.get_mesh()
    mode = softmax_state.resolve(rescale)
    B, K, G, Dk = q.shape
    Dv = v_cache.shape[3]

    shard_ids = jnp.arange(mesh.shape[axis], dtype=jnp.int32)  # see above

    def local(q, kc, vc, nk, nv, pos, sid):
        idx = sid[0]
        S_local = kc.shape[1]
        start = idx * S_local
        slot = jnp.clip(pos - start, 0, S_local - 1)
        owns = (pos >= start) & (pos < start + S_local)
        # single-row conditional writes (see seq_sharded_decode — §Perf D4)
        def write(c, new):
            old = jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)
            row = jnp.where(owns, new[:, None], old)
            return jax.lax.dynamic_update_slice_in_dim(c, row, slot, axis=1)
        kc = write(kc, nk)
        vc = write(vc, nv)
        length = jnp.full((B,), jnp.clip(pos + 1 - start, 0, S_local),
                          jnp.int32)
        m, l, accT = gqa_partial_xla(q, kc, vc, length, scale=scale,
                                     block=block, vary_axis=(axis,),
                                     rescale=mode)
        # weighted-psum combine (one all-reduce, no n-fold gather — §Perf D3)
        m_g = jax.lax.pmax(m, axis)                    # [B,K,G]
        w = softmax_state.merge_weights(m, m_g, mode=mode)
        l_g = jax.lax.psum(l * w, axis)
        acc_g = jax.lax.psum(accT * w[:, :, None, :], axis)
        o = jnp.swapaxes(acc_g / l_g[:, :, None, :], 2, 3)   # [B,K,G,Dv]
        return o.reshape(B, K * G, Dv).astype(v_cache.dtype), kc, vc

    cspec = P(None, axis, None, None)
    return compat.shard_map(
        local, mesh=mesh, axis_names={axis},
        in_specs=(P(), cspec, cspec, P(), P(), P(), P(axis)),
        out_specs=(P(), cspec, cspec),
        check=False,
    )(q, k_cache, v_cache, new_k, new_v, pos, shard_ids)


def gqa_decode_xla(q, k, v, length, *, spec=None, **legacy):
    """GQA decode attention operating NATIVELY on the [B,S,K,hd] cache layout
    (no transpose/copy of the multi-GiB cache — it is streamed in place with
    dynamic_slice). q: [B,K,G,hd]; k,v: [B,S,K,hd*]; length: [B].
    Returns [B, K*G, Dv]. spec.mode "etap" keeps the KV block on the long
    GEMM dim with per-(k,g)-column softmax stats; "standard" is the thin-M
    baseline.  Legacy keywords shim through attn_spec.coerce."""
    spec = attn_spec.coerce(spec, legacy, where="gqa_decode_xla")
    scale, mode = spec.scale, spec.mode
    B, K, G, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[3]
    block = min(spec.block, S)
    nb = _blocks(S, block)
    rs = softmax_state.resolve(spec.rescale)
    qf = q.astype(jnp.float32)

    def step_etap(j, carry):
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        # Sᵀ: KV block on the long dim, per-(k,g) column statistics
        sT = jnp.einsum("bskd,bkgd->bksg", kj, qf.astype(k.dtype),
                        preferred_element_type=jnp.float32) * scale
        pos = j * block + jnp.arange(block, dtype=jnp.int32)
        valid = pos[None, :] < length[:, None]    # [B, block]
        sT = jnp.where(valid[:, None, :, None], sT, NEG_INF)
        return softmax_state.update(
            carry, sT,                            # stats [B,K,G]
            lambda pT: jnp.einsum("bskv,bksg->bkvg", vj, pT.astype(v.dtype),
                                  preferred_element_type=jnp.float32),
            axis=2, mode=rs, expand=lambda c: c[:, :, None, :])

    def step_std(j, carry):
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        s = jnp.einsum("bkgd,bskd->bkgs", qf.astype(k.dtype), kj,
                       preferred_element_type=jnp.float32) * scale
        pos = j * block + jnp.arange(block, dtype=jnp.int32)
        valid = pos[None, :] < length[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        return softmax_state.update(
            carry, s,                             # acc [B,K,G,Dv]
            lambda p: jnp.einsum("bkgs,bskv->bkgv", p.astype(v.dtype), vj,
                                 preferred_element_type=jnp.float32),
            axis=3, mode=rs, expand=lambda c: c[..., None])

    if mode == "etap":
        state = jax.lax.fori_loop(
            0, nb, step_etap, softmax_state.init((B, K, G), (B, K, Dv, G)))
        oT = softmax_state.finalize(state, expand=lambda l: l[:, :, None, :])
        o = jnp.swapaxes(oT, 2, 3)                            # [B,K,G,Dv]
    else:
        state = jax.lax.fori_loop(
            0, nb, step_std, softmax_state.init((B, K, G), (B, K, G, Dv)))
        o = softmax_state.finalize(state, expand=lambda l: l[..., None])
    return o.reshape(B, K * G, Dv).astype(v.dtype)


def gqa_to_grouped(q, k, v):
    """[B,H,D],[B,S,K,D],[B,S,K,Dv] -> grouped (BG=B*K) form + a restorer."""
    B, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, D).reshape(B * K, G, D)
    kg = jnp.swapaxes(k, 1, 2).reshape(B * K, k.shape[1], k.shape[3])
    vg = jnp.swapaxes(v, 1, 2).reshape(B * K, v.shape[1], v.shape[3])

    def restore(o):                                           # [B*K, G, Dv]
        return o.reshape(B, K, G, o.shape[-1]).reshape(B, H, o.shape[-1])
    return qg, kg, vg, restore
