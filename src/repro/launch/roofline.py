"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §7).

TPU v5e constants (per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI per link      : ~50 GB/s

The SPMD-partitioned HLO module is the *per-device* program, so
cost_analysis() FLOPs/bytes are per-chip already:
    compute term    = HLO_FLOPs / peak
    memory term     = HLO_bytes / HBM_bw
    collective term = collective_bytes / link_bw
collective_bytes is parsed from the HLO text: the result-shape bytes of each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes like bf16[16,512]{1,0} or f32[] ; tuples handled by re-scanning
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in (per-device) HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        kind = next((c for c in _COLLECTIVES
                     if op == c or op.startswith(c + ".")), None)
        if kind is None:
            continue
        b = _shape_bytes(m.group(1))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def roofline_terms_from_analysis(analysis: dict) -> dict:
    """Terms from hlo_analysis.analyze() (trip-count-aware — the primary
    source; cost_analysis() counts while bodies once and is kept only as a
    cross-check column)."""
    flops = float(analysis["flops"])
    byts = float(analysis["bytes"])
    coll = float(analysis["collective_bytes"])
    out = {
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "collective_bytes": coll,
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": byts / HBM_BW,
        "t_collective": coll / ICI_BW,
    }
    dom = max(("t_compute", "t_memory", "t_collective"), key=lambda k: out[k])
    out["bottleneck"] = dom[2:]
    t_total = max(out["t_compute"], out["t_memory"], out["t_collective"])
    out["roofline_fraction"] = out["t_compute"] / t_total if t_total > 0 else 0.0
    return out


def roofline_terms(cost: dict, coll: CollectiveStats) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    out = {
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "collective_bytes": float(coll.total_bytes),
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": byts / HBM_BW,
        "t_collective": coll.total_bytes / ICI_BW,
    }
    dom = max(("t_compute", "t_memory", "t_collective"), key=lambda k: out[k])
    out["bottleneck"] = dom[2:]
    t_total = max(out["t_compute"], out["t_memory"], out["t_collective"])
    out["roofline_fraction"] = out["t_compute"] / t_total if t_total > 0 else 0.0
    return out


def splitkv_roofline(BG: int, S: int, H: int, Dk: int, Dv: int,
                     n_splits: int, *, kv_itemsize: int = 2,
                     mla_fused: bool = False) -> dict:
    """Roofline terms for the two-phase split-KV decode pipeline
    (DESIGN.md §3): phase 1 streams the KV cache once and writes per-split
    fp32 (m, ℓ, Accᵀ) stats; phase 2 re-reads the stats and writes O.

    The split count buys parallelism (occupancy factor on the compute term)
    and pays for it in stat traffic — the scheduler's STATS_TRAFFIC_BUDGET
    cap is exactly the requirement that `overhead` stays ≪ 1 here."""
    from repro.kernels.etap.schedule import DEFAULT_CORES

    q_bytes = BG * H * Dk * kv_itemsize
    kv_bytes = BG * S * (Dk if mla_fused else Dk + Dv) * kv_itemsize
    stat_bytes = BG * n_splits * (2 * H + Dv * H) * 4
    o_bytes = BG * H * Dv * kv_itemsize
    flops = 2.0 * BG * S * H * (Dk + Dv)

    occupancy = min(1.0, BG * n_splits / DEFAULT_CORES)
    t_partial_mem = (q_bytes + kv_bytes + stat_bytes) / HBM_BW
    t_partial_compute = flops / (PEAK_FLOPS * occupancy)
    t_combine = (stat_bytes + o_bytes) / HBM_BW
    t_total = max(t_partial_mem, t_partial_compute) + t_combine
    return {
        "kv_bytes": kv_bytes,
        "stat_bytes": stat_bytes,
        "t_partial_mem": t_partial_mem,
        "t_partial_compute": t_partial_compute,
        "t_combine": t_combine,
        "t_total": t_total,
        "occupancy": occupancy,
        "overhead": (2 * stat_bytes + o_bytes) / max(kv_bytes, 1),
        "bottleneck": ("memory" if t_partial_mem >= t_partial_compute
                       else "compute"),
    }


def model_flops(cfg, cell, n_active_params: int) -> float:
    """6·N·D (train) / 2·N·D (inference fwd) convention, attention excluded.
    decode processes global_batch tokens; train/prefill B·S tokens."""
    tokens = cell.global_batch * (1 if cell.is_decode else cell.seq_len)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_active_params * tokens


def active_params(cfg) -> int:
    """Approximate activated parameter count (MoE: top_k of num_experts +
    shared expert; embeddings counted once)."""
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    hd = cfg.resolved_head_dim
    total = V * D
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        if kind == "attn":
            if cfg.attention_kind == "mla":
                m = cfg.mla
                total += D * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * m.qk_head_dim
                total += D * (m.kv_lora_rank + m.qk_rope_head_dim)
                total += m.kv_lora_rank * cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += cfg.num_heads * m.v_head_dim * D
            else:
                total += D * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) \
                    + cfg.num_heads * hd * D
        elif kind == "rglru":
            W = cfg.lru_width or D
            total += 2 * D * W + 2 * W * W + W * D
        elif kind == "ssm":
            di = cfg.ssm.expand * D
            dtr = cfg.ssm.dt_rank or -(-D // 16)
            total += 2 * D * di + di * (dtr + 2 * cfg.ssm.d_state) \
                + dtr * di + di * D
        if kind == "ssm":
            continue
        if cfg.moe_layer(i):
            F = cfg.moe.d_ff_expert or cfg.d_ff
            total += cfg.moe.top_k * 3 * D * F          # activated experts
            if cfg.moe.shared_expert:
                total += 3 * D * F
        elif kind in ("attn", "rglru"):
            total += 3 * D * cfg.d_ff
    return int(total)


def total_params(cfg) -> int:
    """Full parameter count (MoE: all experts)."""
    act = active_params(cfg)
    if cfg.moe is None:
        return act
    F = cfg.moe.d_ff_expert or cfg.d_ff
    n_moe = sum(1 for i in range(cfg.num_layers) if cfg.moe_layer(i))
    extra = n_moe * (cfg.moe.num_experts - cfg.moe.top_k) * 3 * cfg.d_model * F
    return act + extra
