"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each while-loop BODY once — for a
scan-over-layers model that undercounts FLOPs/bytes/collective-traffic by the
layer count (× microbatch count × attention-chunk count…). This module parses
the post-optimization HLO text, reconstructs the computation call graph
(while bodies, conditionals, fusions), extracts loop trip counts from the
condition computations, and accumulates:

    flops            2·M·N·K for dots (+1/elem for elementwise fusions)
    bytes            operand+result bytes at fusion granularity (HBM-traffic
                     approximation: fusion internals stay in registers/VMEM)
    collective_bytes result bytes of all-gather/all-reduce/reduce-scatter/
                     all-to-all/collective-permute, trip-multiplied

Trip counts: the largest s32 literal in the while's condition computation —
exact for scan/fori loops (cond is ``iter < N``), documented heuristic
otherwise.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops that move no HBM bytes of their own
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "while", "call",
             "conditional", "custom-call"}


def _shape_info(shape_str: str):
    """-> (bytes, elements) over all array shapes in the string."""
    total_b, total_e = 0, 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    attrs: str
    inside: str = ""


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    int_constants: list[int] = field(default_factory=list)


_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:\S+?\[[\d,]*\]\S*|\w+\[\]|\w+))\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and "{" in line:
            cur = Computation(mc.group(2))
            comps[cur.name] = cur
            if mc.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, shape, op, rest = mi.groups()
        # operand names: up to the closing paren at depth 0
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        inside, attrs = rest[:i - 1], rest[i:]
        ops_names = _OPERAND_RE.findall(inside)
        cur.instrs.append(Instr(name, shape, op, ops_names, attrs, inside))
        if op == "constant" and shape.startswith(("s32", "s64", "u32")):
            m = re.search(r"constant\((-?\d+)\)", line)
            if m:
                cur.int_constants.append(int(m.group(1)))
    if entry is None and comps:
        entry = list(comps)[-1]
    comps["__entry__"] = comps[entry]
    return comps


def _dot_flops(inst: Instr, shapes: dict[str, str]) -> float:
    rb, re_ = _shape_info(inst.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.attrs)
    if not m or not inst.operands:
        return 2.0 * re_          # fallback
    lhs_shape = shapes.get(inst.operands[0], "")
    dims = _SHAPE_RE.findall(lhs_shape)
    if not dims:
        return 2.0 * re_
    lhs_dims = [int(d) for d in dims[0][1].split(",") if d]
    k = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    return 2.0 * re_ * k


def analyze(text: str) -> dict:
    comps = parse_module(text)
    entry = comps["__entry__"]
    # global name->result-shape map (names are unique per module in practice)
    shapes: dict[str, str] = {}
    for c in comps.values():
        for i in c.instrs:
            shapes[i.name] = i.shape

    memo: dict[tuple, dict] = {}
    _eff_memo: dict[str, dict] = {}

    def eff_param_bytes(cname: str) -> dict:
        """index -> effective read bytes (or None = full) of a fused
        computation's parameters: a parameter consumed ONLY by
        (dynamic-)slice ops reads just the slices, not the (possibly huge)
        base buffer — the KV-cache streaming case. A parameter consumed only
        by dynamic-update-slice writes just the updated region."""
        if cname in _eff_memo:
            return _eff_memo[cname]
        comp = comps.get(cname)
        out: dict = {}
        if comp is not None:
            name_to_idx = {}
            for i in comp.instrs:
                if i.op == "parameter":
                    m = re.match(r"\s*(\d+)", i.inside)
                    if m:
                        name_to_idx[i.name] = int(m.group(1))
            for pname, idx in name_to_idx.items():
                users = [u for u in comp.instrs if pname in u.operands]
                if users and all(u.op in ("dynamic-slice", "slice")
                                 for u in users):
                    out[idx] = sum(_shape_info(u.shape)[0] for u in users)
                elif users and all(
                        u.op == "dynamic-update-slice"
                        and u.operands and u.operands[0] == pname
                        for u in users):
                    out[idx] = sum(
                        _shape_info(shapes.get(u.operands[1], ""))[0]
                        for u in users if len(u.operands) > 1)
        _eff_memo[cname] = out
        return out

    def comp_cost(cname: str, in_fusion: bool) -> dict:
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        memo[key] = {"flops": 0.0, "bytes": 0.0, "coll": 0.0,
                     "coll_by_kind": {}}   # cycle guard
        out = {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_by_kind": {}}
        comp = comps.get(cname)
        if comp is None:
            return out
        seen_reads: set = set()   # each buffer read counted once per execution

        def operand_bytes(inst):
            b = 0
            for o in inst.operands:
                if o in seen_reads:
                    continue
                seen_reads.add(o)
                b += _shape_info(shapes.get(o, ""))[0]
            return b

        for inst in comp.instrs:
            rbytes, relems = _shape_info(inst.shape)
            kind = next((c for c in _COLLECTIVES if inst.op == c
                         or inst.op.startswith(c + "-start")
                         or inst.op.startswith(c + ".")), None)
            if kind:
                out["coll"] += rbytes
                out["coll_by_kind"][kind] = out["coll_by_kind"].get(kind, 0) + rbytes
                out["bytes"] += rbytes
                continue
            if inst.op == "dot":
                out["flops"] += _dot_flops(inst, shapes)
                if not in_fusion:
                    out["bytes"] += rbytes + operand_bytes(inst)
                continue
            if inst.op in ("dynamic-slice", "slice"):
                # reads only the slice, not the (possibly huge) base buffer
                if not in_fusion:
                    out["bytes"] += 2 * rbytes
                continue
            if inst.op == "dynamic-update-slice":
                # in-place update: read+write of the updated region only
                if not in_fusion and len(inst.operands) >= 2:
                    upd = _shape_info(shapes.get(inst.operands[1], ""))[0]
                    out["bytes"] += 2 * upd
                continue
            if inst.op == "while":
                body = _ATTR_COMP["body"].search(inst.attrs)
                cond = _ATTR_COMP["condition"].search(inst.attrs)
                trip = 1
                if cond and comps.get(cond.group(1)):
                    consts = comps[cond.group(1)].int_constants
                    trip = max([c for c in consts if c > 0], default=1)
                if body:
                    sub = comp_cost(body.group(1), in_fusion)
                    for k2 in ("flops", "bytes", "coll"):
                        out[k2] += trip * sub[k2]
                    for k2, v in sub["coll_by_kind"].items():
                        out["coll_by_kind"][k2] = \
                            out["coll_by_kind"].get(k2, 0) + trip * v
                continue
            if inst.op == "fusion":
                m = _ATTR_COMP["calls"].search(inst.attrs)
                eff = {}
                if m:
                    sub = comp_cost(m.group(1), True)   # flops only inside
                    out["flops"] += sub["flops"]
                    out["coll"] += sub["coll"]
                    eff = eff_param_bytes(m.group(1))
                if not in_fusion:
                    b = rbytes
                    for oi, o in enumerate(inst.operands):
                        if o in seen_reads:
                            continue
                        seen_reads.add(o)
                        if oi in eff:
                            b += eff[oi]
                        else:
                            b += _shape_info(shapes.get(o, ""))[0]
                    out["bytes"] += b
                out["flops"] += relems                  # elementwise floor
                continue
            if inst.op in ("call", "conditional"):
                for pat in ("calls", "branches"):
                    m = _ATTR_COMP[pat].search(inst.attrs)
                    if m:
                        for sub_name in _OPERAND_RE.findall(m.group(1)) or \
                                [m.group(1)]:
                            sub = comp_cost(sub_name, in_fusion)
                            for k2 in ("flops", "bytes", "coll"):
                                out[k2] += sub[k2]
                            for k2, v in sub["coll_by_kind"].items():
                                out["coll_by_kind"][k2] = \
                                    out["coll_by_kind"].get(k2, 0) + v
                continue
            if inst.op in _FREE_OPS:
                continue
            # other top-level op (dynamic-slice, copy, convert, reduce, …)
            out["flops"] += relems
            if not in_fusion:
                out["bytes"] += rbytes + operand_bytes(inst)
        memo[key] = out
        return out

    total = comp_cost(entry.name, False)
    return {"flops": total["flops"], "bytes": total["bytes"],
            "collective_bytes": total["coll"],
            "collective_by_kind": total["coll_by_kind"]}
