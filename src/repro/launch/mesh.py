"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first)."""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512
    chips as (pod=2, data=16, model=16); the pod axis composes with data for
    batch/FSDP sharding and carries the cross-pod (DCN-class) collectives."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)  # compat backfills
    return compat.make_mesh(shape, axes, axis_types=types)


def make_host_mesh():
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    return compat.make_mesh((n, 1), ("data", "model"),
                            axis_types=(jax.sharding.AxisType.Auto,) * 2)
