"""Serving driver: batched prefill + autoregressive decode with the ETAP
pipeline (the paper's workload). Real execution on host devices with
reduced configs; production-mesh serving is proven by dryrun.py.

Two cache layouts:

  paged (default) — continuous batching against the block-pool KV cache
      (runtime/paged_cache.py): ragged-length requests are admitted into
      free batch slots whenever the allocator can reserve their full token
      budget, their prompts run as CHUNKED paged prefill interleaved with
      the decode batch under a per-step token budget (--prefill-chunk /
      --token-budget — admission never stalls in-flight decodes), decode
      steps run the whole ragged batch through the paged ETAP kernels, and
      finished sequences release their blocks so queued requests join
      mid-stream.  Throughput is length-aware: only tokens actually
      generated count.

  dense — the legacy fixed-batch path: one jitted lax.scan over steps, every
      sequence runs every step (useful as the single-request-shape baseline
      and for seq-sharded meshes, which the paged path doesn't cover yet).

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_r1_671b \
        --reduced --batch 4 --prompt 64 --gen 32 --mode etap \
        --cache-layout paged --requests 8
"""
from __future__ import annotations

import argparse
import os
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model
from repro.runtime.paged_cache import (KV_LAYOUTS, BlockPool,
                                       layout_for, layout_for_bytes)
from repro.runtime.prefix_cache import PrefixCache


def run_dense(args, cfg) -> dict:
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng, cfg)
    B, S = args.batch, args.prompt
    max_len = S + args.gen
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    logits, cache, pos = model.prefill(params, cfg, {"tokens": tokens}, max_len)
    t_prefill = time.perf_counter() - t0

    # the whole generation is ONE jitted lax.scan over steps (cache donated
    # through the scan carry): decode timing measures the kernels, not
    # per-token Python dispatch / host-device sync overhead.
    def generate(params, cache, first_tok, pos0):
        def step(carry, i):
            tok, cache = carry
            logits, cache = model.decode_step(params, cfg, cache, tok,
                                              pos0 + i, mode=args.mode,
                                              kv_splits=args.kv_splits)
            return (jnp.argmax(logits, axis=-1), cache), tok
        (_, cache), toks = jax.lax.scan(
            step, (first_tok, cache), jnp.arange(args.gen, dtype=jnp.int32))
        return jnp.swapaxes(toks, 0, 1), cache            # [B, gen]

    gen_fn = jax.jit(generate, donate_argnums=(1,))
    cur = jnp.argmax(logits, axis=-1)
    pos0 = jnp.asarray(pos, jnp.int32)
    compiled = gen_fn.lower(params, cache, cur, pos0).compile()

    t0 = time.perf_counter()
    gen, cache = compiled(params, cache, cur, pos0)
    jax.block_until_ready(gen)
    t_decode = time.perf_counter() - t0
    # length-aware accounting: the fixed-batch scan really does generate
    # `gen` tokens for every one of the B sequences (no early exit), so
    # tokens served == B * gen here — but it is counted, not assumed, to
    # match the continuous-batching report.
    tokens_served = int(gen.shape[0] * gen.shape[1])
    print(f"[serve] arch={args.arch} layout=dense mode={args.mode} "
          f"B={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f}ms; decode "
          f"{t_decode/args.gen*1e3:.2f}ms/token "
          f"({tokens_served/t_decode:.1f} tok/s, {tokens_served} tokens)")
    print(f"[serve] sample generation (seq 0): {gen[0][:16].tolist()}")
    return {"tokens": gen, "t_prefill": t_prefill, "t_decode": t_decode,
            "tokens_served": tokens_served}


def _make_requests(args, vocab: int):
    """Ragged request stream: prompt/gen lengths drawn from a few quantized
    buckets (bounds prefill re-tracing) around --prompt/--gen.

    ``--shared-prefix N`` makes every prompt start with the SAME N tokens
    (a shared system prompt) followed by a per-request random tail — the
    prefix-cache workload.  The stream is identical for a given seed
    whether or not the prefix cache is enabled (the flag only changes how
    it is served), which is what makes the on/off bitwise-equivalence
    check meaningful."""
    rng = np.random.default_rng(args.seed + 1)
    # buckets never exceed --prompt: the pool layout is sized for
    # prompt + gen, so every request must fit it by construction
    p_buckets = sorted({max(1, args.prompt // 2), max(1, 3 * args.prompt // 4),
                        args.prompt})
    g_buckets = sorted({max(1, args.gen // 2), args.gen})
    shared = None
    if args.shared_prefix:
        assert args.shared_prefix < args.prompt, \
            "--shared-prefix must leave room for a per-request tail"
        shared = rng.integers(0, vocab, size=(args.shared_prefix,))
    reqs = []
    for i in range(args.requests):
        plen = int(rng.choice(p_buckets))
        glen = int(rng.choice(g_buckets))
        if shared is None:
            toks = rng.integers(0, vocab, size=(plen,))
        else:
            plen = max(plen, args.shared_prefix + 1)
            tail = rng.integers(0, vocab, size=(plen - args.shared_prefix,))
            toks = np.concatenate([shared, tail])
        reqs.append({"id": i, "prompt": jnp.asarray(toks, jnp.int32),
                     "gen": glen})
    return reqs


def run_paged(args, cfg) -> dict:
    """Continuous-batching serve loop: CHUNKED paged prefill interleaved
    with decode under a per-step token budget (DESIGN.md §9).

    Per step:
      (1) admit queued requests COLD into free slots while the block pool
          can reserve their full budget (admission refusal = stay queued —
          never a mid-flight OOM).  Admission is CACHE-AWARE when the
          prefix cache is on (--prefix-cache, DESIGN.md §10): the radix
          tree is walked with the request's prompt, the matched
          block-aligned prefix is mapped into the slot's block table with
          a refcount bump per block (zero prefill tokens spent on it), and
          under pool pressure LRU trie-only leaves are evicted to the free
          list before refusing.  Admission reserves blocks only; no
          prompt tokens run yet.
      (2) spend the step's token budget (``--token-budget``): the decode
          batch (one token per decoding slot) is committed first, then
          prefill chunks of ``--prefill-chunk`` tokens from admitted-but-
          cold requests are appended FCFS while they fit the remainder —
          so a long prompt never head-of-line-blocks in-flight decodes
          (chunked-prefill continuous batching, vLLM/Sarathi-style).  Each
          chunk runs ``model.prefill_chunk`` straight into the request's
          pool blocks: no dense staging cache, no post-hoc scatter, peak
          extra memory = one chunk.  When nothing is decoding, one chunk
          always runs even if it exceeds the budget (progress guarantee).
          A prefix-cache hit resumes prefill at the match offset; the
          first tail chunk is trimmed onto the GLOBAL chunk grid
          (positions k*chunk), so for chunk-aligned matches every tail
          chunk has exactly the shape it would have had uncached — that is
          what makes cached decode output BITWISE identical to uncached,
          not merely close (DESIGN.md §10).  A request that finishes its
          prompt INSERTS its full prompt blocks into the trie right away
          (not at release), so queued requests share them while the donor
          is still decoding; only tail tokens were charged to the budget.
      (3) one jitted paged decode step over the decoding slots (cold
          slots' table rows are masked to the null block, so the decode
          write can't touch a half-prefilled prompt), then retire finished
          sequences and release their blocks.

    Re-tracing is bounded: prefill_chunk compiles once per distinct chunk
    size, and chunk sizes are min(--prefill-chunk, remaining prompt) over
    the quantized prompt buckets of :func:`_make_requests`."""
    params = model.init(jax.random.PRNGKey(args.seed), cfg)
    B = args.batch
    max_total = args.prompt + args.gen
    layout = layout_for(B, max_total, block_size=args.page_size,
                        spare_blocks=args.spare_blocks)
    if args.kv_dtype != "fp":
        # capacity accounting (DESIGN.md §11): hold the POOL BYTE BUDGET
        # fixed at what the fp layout would have spent for --batch slots
        # and let the cheaper quantized rows buy more blocks — and with
        # them more concurrent batch slots (~2x at int8 for bf16 configs).
        fp_bytes = model.paged_row_bytes(cfg, "fp")
        q_bytes = model.paged_row_bytes(cfg, args.kv_dtype)
        budget = (layout.num_blocks - 1) * layout.block_size * fp_bytes
        layout, B = layout_for_bytes(budget, q_bytes, max_total,
                                     block_size=args.page_size,
                                     spare_blocks=args.spare_blocks)
    bp = BlockPool(layout, B)
    prefix = PrefixCache(layout.block_size) if args.prefix_cache else None
    cache = model.init_paged_cache(cfg, layout, kv_dtype=args.kv_dtype)
    waiting = deque(_make_requests(args, cfg.vocab_size))
    n_requests = len(waiting)
    chunk = max(1, args.prefill_chunk)
    # auto budget: the whole decode batch plus one prefill chunk per step
    budget = args.token_budget if args.token_budget > 0 else B + chunk

    # the cache pytree is DONATED through both jitted entries (as the dense
    # path donates through its scan carry): the pool is updated in place
    # instead of copied per call, keeping admission's peak extra memory at
    # one chunk, not a second pool.
    step_fn = jax.jit(lambda p, c, t, table, lengths: model.decode_step(
        p, cfg, c, t, None, mode=args.mode, kv_splits=args.kv_splits,
        cache_layout="paged", block_table=table, lengths=lengths),
        donate_argnums=(1,))
    # warm the decode step OUTSIDE the timed region (the dense path also
    # compiles before its timer): all slots inactive → the dummy rows land
    # in the reserved null block, so rebinding the returned cache (the
    # donated input is gone) leaves every real pool row untouched.
    table0, lengths0 = bp.device_views()
    logits0, cache = step_fn(params, cache, jnp.zeros((B,), jnp.int32),
                             table0, lengths0)
    jax.block_until_ready(logits0)

    # one jitted entry — jax.jit caches per chunk-size shape on its own
    prefill_fn = jax.jit(lambda p, cch, t, table, lens: model.prefill_chunk(
        p, cfg, cch, t, table, lens, mode=args.mode), donate_argnums=(1,))

    cur = np.zeros((B,), np.int64)            # next token per slot
    remaining = np.zeros((B,), np.int64)      # gen budget left per slot
    decoding = np.zeros((B,), bool)           # prompt fully prefilled
    pf_pos = np.zeros((B,), np.int64)         # prompt tokens prefilled
    prompt_of = [None] * B
    gen_of = np.zeros((B,), np.int64)
    admit_seq = np.zeros((B,), np.int64)      # FCFS order among cold slots
    req_of = [None] * B
    outputs = {}                              # id -> [generated tokens]
    tokens_served = 0
    refused_ids = set()                       # requests refused >= once
    steps = 0                                 # decode steps
    prefill_chunks = 0
    interleaved_steps = 0                     # decode step + >=1 chunk
    n_admitted = 0
    prefill_tokens = 0                        # prompt tokens actually run
    prefill_tokens_saved = 0                  # prompt tokens skipped (hits)
    t_prefill = 0.0

    t0 = time.perf_counter()
    while waiting or bp.active.any():
        # ---- (1) admit: FCFS, cache-aware while the prefix cache is on
        while waiting:
            req = waiting[0]
            prompt_np = np.asarray(req["prompt"])
            plen = int(prompt_np.shape[0])
            total = plen + req["gen"]
            chain, matched = ([], 0)
            if prefix is not None and bp.free_slots():
                # record=False: a refused request is re-matched every step
                # (its match can GROW while it waits), so stats are counted
                # once, on successful admission, not per retry
                chain, matched = prefix.match(prompt_np, record=False)
                # FULL shared blocks only: a chain whose last block is
                # partial (prefix ends mid-block) still needs a FRESH
                # block for that logical position — the eager-COW copy
                # target — so it must count against the free list, not as
                # shared.  len(chain) would over-count by one there and
                # let can_admit say yes at exactly-one-block-short
                # occupancy (admit_shared itself counts full blocks and
                # would then refuse — tests/test_paged.py pins the
                # boundary).  Trie matches are block-aligned today, which
                # made this dormant, not correct.
                n_full = matched // layout.block_size
                # pressure: reclaim LRU trie-only leaves until the fresh
                # need fits (the matched chain itself is protected — its
                # blocks are trie-exclusive until admit_shared bumps them).
                # Evict ONLY when eviction can actually make the admission
                # fit: block shortage is the one evictable-away refusal —
                # a full batch, an over-max_len request, or an evictable
                # supply short of the need must refuse WITHOUT trading
                # away cache state other requests would have hit.
                protect = frozenset(chain)
                need = layout.blocks_for(total) - n_full
                if (total <= layout.max_len and need > bp.num_free
                        and bp.num_free + prefix.reclaimable(
                            bp, protect) >= need):
                    while not bp.can_admit(total, n_shared=n_full):
                        if prefix.evict_lru(bp, protect=protect) is None:
                            break
            if chain:
                got = bp.admit_shared(matched, total, chain)
                slot = None
                if got is not None:
                    slot, cow = got
                    # trie matches are block-aligned so cow is empty today;
                    # a mid-block match (divergence inside a block) copies
                    # the partial donor block into the slot's private block
                    # before any token is written
                    for src, dst in cow:
                        cache = model.copy_paged_block(cache, src, dst)
            else:
                slot = bp.admit(0, total)
            if slot is None:
                if bp.active.any():
                    refused_ids.add(req["id"])
                    break
                raise RuntimeError(
                    f"request {req['id']} ({total} tokens) can never fit "
                    f"the pool ({layout.num_blocks - 1} blocks)")
            waiting.popleft()
            req_of[slot] = req["id"]
            prompt_of[slot] = req["prompt"]
            gen_of[slot] = req["gen"]
            pf_pos[slot] = matched             # prefill resumes at the match
            prefill_tokens_saved += matched
            if prefix is not None:
                prefix.record(matched)         # one lookup per admission
            decoding[slot] = False
            admit_seq[slot] = n_admitted
            n_admitted += 1
            outputs[req["id"]] = []

        dec_mask = bp.active & decoding       # fixed for the whole step: a
        # slot finishing its prompt below starts decoding NEXT step
        decode_slots = [b for b in range(B) if dec_mask[b]]
        spent = len(decode_slots)             # decode tokens this step

        # ---- (2) prefill chunks from cold slots under the budget
        pf_tokens = 0
        cold = sorted((b for b in range(B)
                       if bp.active[b] and not decoding[b]),
                      key=lambda b: admit_seq[b])
        for b in cold:
            plen = int(prompt_of[b].shape[0])
            # trim the first tail chunk onto the global chunk grid: after a
            # prefix-cache hit at a non-chunk-multiple offset, the next
            # chunk ends at the grid point, so every later chunk has the
            # exact shape the uncached run would have used (bitwise-equal
            # decode, DESIGN.md §10).  Uncached (pf_pos % chunk == 0) this
            # is the plain min(chunk, remaining).
            c = min(chunk - int(pf_pos[b]) % chunk, plen - int(pf_pos[b]))
            if spent + c > budget and spent > 0:
                break                         # budget spent — defer chunk
            tp = time.perf_counter()
            toks_c = prompt_of[b][None, int(pf_pos[b]):int(pf_pos[b]) + c]
            trow = jnp.array(bp.table[b:b + 1])
            lrow = jnp.array(bp.lengths[b:b + 1])
            logits, cache = prefill_fn(params, cache, toks_c, trow, lrow)
            jax.block_until_ready(logits)
            t_prefill += time.perf_counter() - tp
            bp.extend(b, c)
            pf_pos[b] += c
            spent += c
            pf_tokens += c
            prefill_tokens += c
            prefill_chunks += 1
            if int(pf_pos[b]) == plen:        # prompt done -> start decoding
                cur[b] = int(jnp.argmax(logits[0, -1]))
                remaining[b] = gen_of[b]
                decoding[b] = True
                if prefix is not None:
                    # cache the prompt's full blocks NOW (not at release):
                    # queued requests share them while this one decodes
                    prefix.insert(np.asarray(prompt_of[b]),
                                  bp.block_ids(b), bp)

        # ---- (3) one ragged decode step over the decoding slots
        if decode_slots:
            # mask cold slots to the null block: the decode write for them
            # must not land inside a half-prefilled prompt
            table_m = bp.table.copy()
            lens_m = bp.lengths.copy()
            for b in range(B):
                if not dec_mask[b]:
                    table_m[b] = 0
                    lens_m[b] = 0
            logits, cache = step_fn(params, cache, jnp.array(cur, jnp.int32),
                                    jnp.array(table_m), jnp.array(lens_m))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            steps += 1
            if pf_tokens:
                interleaved_steps += 1

            # ---- retire / bookkeep (host side — the scheduler's job)
            for b in decode_slots:
                outputs[req_of[b]].append(int(cur[b]))
                tokens_served += 1
                bp.append(b)
                remaining[b] -= 1
                cur[b] = nxt[b]
                if remaining[b] == 0:
                    bp.release(b)
                    req_of[b] = None
                    decoding[b] = False
    t_total = time.perf_counter() - t0
    t_decode = t_total - t_prefill

    pstats = prefix.stats() if prefix is not None else None
    # true tokens served (NOT batch * gen: sequences join/leave mid-stream)
    print(f"[serve] arch={args.arch} layout=paged mode={args.mode} B={B} "
          f"requests={n_requests} page={layout.block_size} "
          f"blocks={layout.num_blocks - 1} chunk={chunk} budget={budget} "
          f"kv_dtype={args.kv_dtype} "
          f"prefix_cache={'on' if prefix is not None else 'off'}")
    print(f"[serve] {tokens_served} tokens in {steps} decode steps "
          f"({tokens_served / max(steps, 1):.2f} tokens/step occupancy); "
          f"{prefill_chunks} prefill chunks, {interleaved_steps} steps "
          f"interleaved prefill+decode; prefill {t_prefill*1e3:.1f}ms; "
          f"decode {t_decode*1e3:.1f}ms "
          f"({tokens_served/max(t_decode, 1e-9):.1f} tok/s); "
          f"requests refused at least once: {len(refused_ids)}")
    print(f"[serve] token split: {prefill_tokens} prefill + {tokens_served} "
          f"decode run, {prefill_tokens_saved} prefill skipped"
          + (f"; prefix cache: {pstats['hits']}/{pstats['lookups']} hits "
             f"({pstats['hit_rate']:.0%}), {pstats['cached_blocks']} blocks "
             f"cached, {pstats['evictions']} evicted" if pstats else ""))
    first = outputs[0][:16] if outputs.get(0) else []
    print(f"[serve] sample generation (request 0): {first}")
    return {"outputs": outputs, "tokens_served": tokens_served,
            "batch_slots": B, "kv_dtype": args.kv_dtype,
            "pool_blocks": layout.num_blocks - 1,
            "steps": steps, "refusals": len(refused_ids),
            "prefill_chunks": prefill_chunks,
            "interleaved_steps": interleaved_steps,
            "prefill_tokens": prefill_tokens,
            "decode_tokens": tokens_served,
            "prefill_tokens_saved": prefill_tokens_saved,
            "prefix": pstats,
            "t_prefill": t_prefill, "t_decode": t_decode}


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.cache_layout == "dense":
        return run_dense(args, cfg)
    return run_paged(args, cfg)


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_r1_671b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4,
                    help="batch slots (paged) / batch size (dense)")
    ap.add_argument("--prompt", type=int, default=64,
                    help="max prompt length (paged draws ragged lengths)")
    ap.add_argument("--gen", type=int, default=32,
                    help="max tokens to generate per request")
    ap.add_argument("--mode", default="etap", choices=["etap", "standard"])
    ap.add_argument("--cache-layout", default="paged",
                    choices=["dense", "paged"],
                    help="KV cache layout; paged = continuous batching "
                         "(the serving default), dense = fixed-batch scan")
    ap.add_argument("--requests", type=int, default=8,
                    help="ragged request count for the paged serve loop")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV block (FlashMLA uses 64)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per paged prefill chunk "
                         "(chunked-prefill continuous batching)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-step token budget shared by the decode batch "
                         "and prefill chunks (0 = batch + prefill-chunk)")
    ap.add_argument("--spare-blocks", type=int, default=0,
                    help="extra pool blocks beyond batch*max_blocks")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="radix-tree prefix cache: share KV blocks of "
                         "common prompt prefixes across requests and skip "
                         "their prefill (--no-prefix-cache disables)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of a common prompt prefix shared by every "
                         "generated request (the prefix-cache workload; "
                         "0 = fully independent prompts)")
    ap.add_argument("--kv-splits", type=int, default=None,
                    help="split-KV count for decode attention "
                         "(default: auto-scheduled)")
    ap.add_argument("--kv-dtype", default=os.environ.get("REPRO_KV_DTYPE",
                                                         "fp"),
                    choices=list(KV_LAYOUTS),
                    help="paged KV storage layout (DESIGN.md §11): fp = "
                         "config dtype; int8/fp8 store per-row quantized "
                         "codes + (scale, zp) and admit ~2x the sequences "
                         "under the same pool byte budget (env default: "
                         "REPRO_KV_DTYPE — the CI int8 leg's hook)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
