"""Serving driver: batched prefill + autoregressive decode with the ETAP
pipeline (the paper's workload). Real execution on host devices with
reduced configs; production-mesh serving is proven by dryrun.py.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_r1_671b \
        --reduced --batch 4 --prompt 64 --gen 32 --mode etap
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng, cfg)
    B, S = args.batch, args.prompt
    max_len = S + args.gen
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    logits, cache, pos = model.prefill(params, cfg, {"tokens": tokens}, max_len)
    t_prefill = time.perf_counter() - t0

    # the whole generation is ONE jitted lax.scan over steps (cache donated
    # through the scan carry): decode timing measures the kernels, not
    # per-token Python dispatch / host-device sync overhead.
    def generate(params, cache, first_tok, pos0):
        def step(carry, i):
            tok, cache = carry
            logits, cache = model.decode_step(params, cfg, cache, tok,
                                              pos0 + i, mode=args.mode,
                                              kv_splits=args.kv_splits)
            return (jnp.argmax(logits, axis=-1), cache), tok
        (_, cache), toks = jax.lax.scan(
            step, (first_tok, cache), jnp.arange(args.gen, dtype=jnp.int32))
        return jnp.swapaxes(toks, 0, 1), cache            # [B, gen]

    gen_fn = jax.jit(generate, donate_argnums=(1,))
    cur = jnp.argmax(logits, axis=-1)
    pos0 = jnp.asarray(pos, jnp.int32)
    compiled = gen_fn.lower(params, cache, cur, pos0).compile()

    t0 = time.perf_counter()
    gen, cache = compiled(params, cache, cur, pos0)
    jax.block_until_ready(gen)
    t_decode = time.perf_counter() - t0
    print(f"[serve] arch={args.arch} mode={args.mode} B={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f}ms; decode "
          f"{t_decode/args.gen*1e3:.2f}ms/token "
          f"({B*args.gen/t_decode:.1f} tok/s)")
    print(f"[serve] sample generation (seq 0): {gen[0][:16].tolist()}")
    return {"tokens": gen, "t_prefill": t_prefill, "t_decode": t_decode}


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_r1_671b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mode", default="etap", choices=["etap", "standard"])
    ap.add_argument("--kv-splits", type=int, default=None,
                    help="split-KV count for decode attention "
                         "(default: auto-scheduled)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
