"""Serving driver: batched prefill + autoregressive decode with the ETAP
pipeline (the paper's workload). Real execution on host devices with
reduced configs; production-mesh serving is proven by dryrun.py.

Two cache layouts:

  paged (default) — continuous batching against the block-pool KV cache
      (runtime/paged_cache.py): ragged-length requests are admitted into
      free batch slots whenever the allocator can reserve their full token
      budget, decode steps run the whole ragged batch through the paged
      ETAP kernels, and finished sequences release their blocks so queued
      requests join mid-stream.  Throughput is length-aware: only tokens
      actually generated count.

  dense — the legacy fixed-batch path: one jitted lax.scan over steps, every
      sequence runs every step (useful as the single-request-shape baseline
      and for seq-sharded meshes, which the paged path doesn't cover yet).

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_r1_671b \
        --reduced --batch 4 --prompt 64 --gen 32 --mode etap \
        --cache-layout paged --requests 8
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model
from repro.runtime.paged_cache import BlockPool, layout_for


def run_dense(args, cfg) -> dict:
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng, cfg)
    B, S = args.batch, args.prompt
    max_len = S + args.gen
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    logits, cache, pos = model.prefill(params, cfg, {"tokens": tokens}, max_len)
    t_prefill = time.perf_counter() - t0

    # the whole generation is ONE jitted lax.scan over steps (cache donated
    # through the scan carry): decode timing measures the kernels, not
    # per-token Python dispatch / host-device sync overhead.
    def generate(params, cache, first_tok, pos0):
        def step(carry, i):
            tok, cache = carry
            logits, cache = model.decode_step(params, cfg, cache, tok,
                                              pos0 + i, mode=args.mode,
                                              kv_splits=args.kv_splits)
            return (jnp.argmax(logits, axis=-1), cache), tok
        (_, cache), toks = jax.lax.scan(
            step, (first_tok, cache), jnp.arange(args.gen, dtype=jnp.int32))
        return jnp.swapaxes(toks, 0, 1), cache            # [B, gen]

    gen_fn = jax.jit(generate, donate_argnums=(1,))
    cur = jnp.argmax(logits, axis=-1)
    pos0 = jnp.asarray(pos, jnp.int32)
    compiled = gen_fn.lower(params, cache, cur, pos0).compile()

    t0 = time.perf_counter()
    gen, cache = compiled(params, cache, cur, pos0)
    jax.block_until_ready(gen)
    t_decode = time.perf_counter() - t0
    # length-aware accounting: the fixed-batch scan really does generate
    # `gen` tokens for every one of the B sequences (no early exit), so
    # tokens served == B * gen here — but it is counted, not assumed, to
    # match the continuous-batching report.
    tokens_served = int(gen.shape[0] * gen.shape[1])
    print(f"[serve] arch={args.arch} layout=dense mode={args.mode} "
          f"B={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f}ms; decode "
          f"{t_decode/args.gen*1e3:.2f}ms/token "
          f"({tokens_served/t_decode:.1f} tok/s, {tokens_served} tokens)")
    print(f"[serve] sample generation (seq 0): {gen[0][:16].tolist()}")
    return {"tokens": gen, "t_prefill": t_prefill, "t_decode": t_decode,
            "tokens_served": tokens_served}


def _make_requests(args, vocab: int):
    """Ragged request stream: prompt/gen lengths drawn from a few quantized
    buckets (bounds prefill re-tracing) around --prompt/--gen."""
    rng = np.random.default_rng(args.seed + 1)
    # buckets never exceed --prompt: the pool layout is sized for
    # prompt + gen, so every request must fit it by construction
    p_buckets = sorted({max(1, args.prompt // 2), max(1, 3 * args.prompt // 4),
                        args.prompt})
    g_buckets = sorted({max(1, args.gen // 2), args.gen})
    reqs = []
    for i in range(args.requests):
        plen = int(rng.choice(p_buckets))
        glen = int(rng.choice(g_buckets))
        toks = rng.integers(0, vocab, size=(plen,))
        reqs.append({"id": i, "prompt": jnp.asarray(toks, jnp.int32),
                     "gen": glen})
    return reqs


def run_paged(args, cfg) -> dict:
    """Continuous-batching serve loop over the paged KV cache.

    Per step: (1) admit queued requests into free slots while the block
    pool can reserve their full budget (admission refusal = stay queued —
    never a mid-flight OOM), (2) one jitted paged decode step over the
    whole ragged batch, (3) retire finished sequences and release their
    blocks.  FCFS admission (head-of-line blocking is the simple policy;
    slot/pool pressure shows up as `refusals` — the number of distinct
    requests that were refused at least once before admission)."""
    params = model.init(jax.random.PRNGKey(args.seed), cfg)
    B = args.batch
    max_total = args.prompt + args.gen
    layout = layout_for(B, max_total, block_size=args.page_size,
                        spare_blocks=args.spare_blocks)
    bp = BlockPool(layout, B)
    cache = model.init_paged_cache(cfg, layout)
    waiting = deque(_make_requests(args, cfg.vocab_size))
    n_requests = len(waiting)

    step_fn = jax.jit(lambda p, c, t, table, lengths: model.decode_step(
        p, cfg, c, t, None, mode=args.mode, kv_splits=args.kv_splits,
        cache_layout="paged", block_table=table, lengths=lengths))
    # warm the decode step OUTSIDE the timed region (the dense path also
    # compiles before its timer): all slots inactive → the dummy rows land
    # in the null block, the real pool state is untouched, and the cache
    # that call returns is discarded.
    table0, lengths0 = bp.device_views()
    jax.block_until_ready(step_fn(
        params, cache, jnp.zeros((B,), jnp.int32), table0, lengths0)[0])

    cur = np.zeros((B,), np.int64)            # next token per slot
    remaining = np.zeros((B,), np.int64)      # gen budget left per slot
    req_of = [None] * B
    outputs = {}                              # id -> [generated tokens]
    tokens_served = 0
    refused_ids = set()                       # requests refused >= once
    steps = 0
    t_prefill = 0.0

    t0 = time.perf_counter()
    while waiting or bp.active.any():
        # ---- admit: FCFS while a slot + the full block budget fit
        while waiting:
            req = waiting[0]
            plen = int(req["prompt"].shape[0])
            total = plen + req["gen"]
            slot = bp.admit(plen, total)
            if slot is None:
                if bp.active.any():
                    refused_ids.add(req["id"])
                    break
                raise RuntimeError(
                    f"request {req['id']} ({total} tokens) can never fit "
                    f"the pool ({layout.num_blocks - 1} blocks)")
            waiting.popleft()
            tp = time.perf_counter()
            logits, pcache, _ = model.prefill(
                params, cfg, {"tokens": req["prompt"][None, :]}, max_len=plen)
            need = layout.blocks_for(plen + req["gen"])
            cache = model.write_prefill_paged(
                cfg, cache, pcache, bp.block_ids(slot)[:need])
            t_prefill += time.perf_counter() - tp
            cur[slot] = int(jnp.argmax(logits[0], -1))
            remaining[slot] = req["gen"]
            req_of[slot] = req["id"]
            outputs[req["id"]] = []

        # ---- one ragged decode step over every active slot
        table, lengths = bp.device_views()
        logits, cache = step_fn(params, cache,
                                jnp.array(cur, jnp.int32), table, lengths)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        steps += 1

        # ---- retire / bookkeep (host side — the scheduler's job)
        for b in range(B):
            if not bp.active[b]:
                continue
            outputs[req_of[b]].append(int(cur[b]))
            tokens_served += 1
            bp.append(b)
            remaining[b] -= 1
            cur[b] = nxt[b]
            if remaining[b] == 0:
                bp.release(b)
                req_of[b] = None
    t_total = time.perf_counter() - t0
    t_decode = t_total - t_prefill

    # true tokens served (NOT batch * gen: sequences join/leave mid-stream)
    print(f"[serve] arch={args.arch} layout=paged mode={args.mode} B={B} "
          f"requests={n_requests} page={layout.block_size} "
          f"blocks={layout.num_blocks - 1}")
    print(f"[serve] {tokens_served} tokens in {steps} steps "
          f"({tokens_served / max(steps, 1):.2f} tokens/step occupancy); "
          f"prefill {t_prefill*1e3:.1f}ms; decode {t_decode*1e3:.1f}ms "
          f"({tokens_served/max(t_decode, 1e-9):.1f} tok/s); "
          f"requests refused at least once: {len(refused_ids)}")
    first = outputs[0][:16] if outputs.get(0) else []
    print(f"[serve] sample generation (request 0): {first}")
    return {"outputs": outputs, "tokens_served": tokens_served,
            "steps": steps, "refusals": len(refused_ids),
            "t_prefill": t_prefill, "t_decode": t_decode}


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.cache_layout == "dense":
        return run_dense(args, cfg)
    return run_paged(args, cfg)


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_r1_671b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4,
                    help="batch slots (paged) / batch size (dense)")
    ap.add_argument("--prompt", type=int, default=64,
                    help="max prompt length (paged draws ragged lengths)")
    ap.add_argument("--gen", type=int, default=32,
                    help="max tokens to generate per request")
    ap.add_argument("--mode", default="etap", choices=["etap", "standard"])
    ap.add_argument("--cache-layout", default="paged",
                    choices=["dense", "paged"],
                    help="KV cache layout; paged = continuous batching "
                         "(the serving default), dense = fixed-batch scan")
    ap.add_argument("--requests", type=int, default=8,
                    help="ragged request count for the paged serve loop")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV block (FlashMLA uses 64)")
    ap.add_argument("--spare-blocks", type=int, default=0,
                    help="extra pool blocks beyond batch*max_blocks")
    ap.add_argument("--kv-splits", type=int, default=None,
                    help="split-KV count for decode attention "
                         "(default: auto-scheduled)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
