"""Serving driver: batched prefill + autoregressive decode with the ETAP
pipeline (the paper's workload). Real execution on host devices with
reduced configs; production-mesh serving is proven by dryrun.py.

Two cache layouts:

  paged (default) — continuous batching against the block-pool KV cache
      (runtime/paged_cache.py): ragged-length requests are admitted into
      free batch slots whenever the allocator can reserve their full token
      budget, their prompts run as CHUNKED paged prefill interleaved with
      the decode batch under a per-step token budget (--prefill-chunk /
      --token-budget — admission never stalls in-flight decodes), decode
      steps run the whole ragged batch through the paged ETAP kernels, and
      finished sequences release their blocks so queued requests join
      mid-stream.  Throughput is length-aware: only tokens actually
      generated count.

  dense — the legacy fixed-batch path: one jitted lax.scan over steps, every
      sequence runs every step (useful as the single-request-shape baseline
      and for seq-sharded meshes, which the paged path doesn't cover yet).

Observability (DESIGN.md §15): every stat a run reports is recorded into
one per-run MetricsRegistry (runtime/telemetry.py) and the ``[serve]``
summary renders from its snapshot (launch/obs.py) — ``--metrics-out``
archives the same snapshot as JSON.  ``--trace-out`` records the request
lifecycle + engine spans into a bounded ring buffer and exports Chrome
trace-event JSON; ``--profile-kernels N`` times every N-th attention
launch at the ``attn_entry`` choke point.  Telemetry never touches token
streams: telemetry-on output is bitwise identical to telemetry-off at
default sampling (tests/test_telemetry.py + BENCH_obs.json gate it).

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek_r1_671b \
        --reduced --batch 4 --prompt 64 --gen 32 --mode etap \
        --cache-layout paged --requests 8 --trace-out /tmp/trace.json
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.core import attn_spec
from repro.kernels import softmax_state
from repro.launch import obs
from repro.models import model
from repro.runtime import scheduler, spec_decode, telemetry
from repro.runtime.fault_tolerance import (FailureInjector,
                                           HeartbeatRegistry, WorkerFailure)
from repro.runtime.paged_cache import (KV_LAYOUTS, BlockPool,
                                       layout_for, layout_for_bytes)
from repro.runtime.prefix_cache import PrefixCache


def run_dense(args, cfg) -> dict:
    reg = telemetry.MetricsRegistry()
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng, cfg)
    B, S = args.batch, args.prompt
    max_len = S + args.gen
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    logits, cache, pos = model.prefill(params, cfg, {"tokens": tokens}, max_len)
    t_prefill = time.perf_counter() - t0

    # the whole generation is ONE jitted lax.scan over steps (cache donated
    # through the scan carry): decode timing measures the kernels, not
    # per-token Python dispatch / host-device sync overhead.
    spec = attn_spec.AttnSpec(mode=args.mode, kv_splits=args.kv_splits)

    def generate(params, cache, first_tok, pos0):
        def step(carry, i):
            tok, cache = carry
            logits, cache = model.decode_step(params, cfg, cache, tok,
                                              pos0 + i, spec=spec)
            return (jnp.argmax(logits, axis=-1), cache), tok
        (_, cache), toks = jax.lax.scan(
            step, (first_tok, cache), jnp.arange(args.gen, dtype=jnp.int32))
        return jnp.swapaxes(toks, 0, 1), cache            # [B, gen]

    gen_fn = jax.jit(generate, donate_argnums=(1,))
    cur = jnp.argmax(logits, axis=-1)
    pos0 = jnp.asarray(pos, jnp.int32)
    compiled = gen_fn.lower(params, cache, cur, pos0).compile()

    t0 = time.perf_counter()
    gen, cache = compiled(params, cache, cur, pos0)
    jax.block_until_ready(gen)
    t_decode = time.perf_counter() - t0
    # length-aware accounting: the fixed-batch scan really does generate
    # `gen` tokens for every one of the B sequences (no early exit), so
    # tokens served == B * gen here — but it is counted, not assumed, to
    # match the continuous-batching report.
    tokens_served = int(gen.shape[0] * gen.shape[1])
    reg.counter("serve/decode_tokens").inc(tokens_served)
    reg.counter("serve/decode_steps").inc(int(args.gen))
    snap = reg.snapshot()
    if args.metrics_out:
        obs.write_metrics(args.metrics_out, snap,
                          config=f"serve:{args.arch}:dense")
    for line in obs.summarize_dense(snap, {
            "arch": args.arch, "mode": args.mode,
            "rescale": softmax_state.default_mode(),
            "batch": B, "prompt": S, "gen": args.gen,
            "t_prefill": t_prefill, "t_decode": t_decode,
            "metrics_path": args.metrics_out,
            "sample": gen[0][:16].tolist()}):
        obs.emit(line)
    return {"tokens": gen, "t_prefill": t_prefill, "t_decode": t_decode,
            "tokens_served": tokens_served, "metrics": snap}


def _make_requests(args, vocab: int):
    """Ragged request stream: prompt/gen lengths drawn from a few quantized
    buckets (bounds prefill re-tracing) around --prompt/--gen.

    ``--shared-prefix N`` makes every prompt start with the SAME N tokens
    (a shared system prompt) followed by a per-request random tail — the
    prefix-cache workload.  The stream is identical for a given seed
    whether or not the prefix cache is enabled (the flag only changes how
    it is served), which is what makes the on/off bitwise-equivalence
    check meaningful.

    Multi-tenant knobs (DESIGN.md §12) ride on SEPARATE rng streams so
    enabling them never perturbs the prompt/length draws — the same seed
    serves the same tokens contended or uncontended, which is what makes
    the preempted-vs-uncontended bitwise check meaningful:
      · --priority-classes N draws a class in [0, N) per request
        (0 = most important);
      · --arrival-rate R staggers arrivals over scheduler ticks —
        Poisson inter-arrival gaps (--trace uniform) or adversarial
        over-admission bursts of --burst-size simultaneous requests
        (--trace burst)."""
    rng = np.random.default_rng(args.seed + 1)
    # buckets never exceed --prompt: the pool layout is sized for
    # prompt + gen, so every request must fit it by construction
    p_buckets = sorted({max(1, args.prompt // 2), max(1, 3 * args.prompt // 4),
                        args.prompt})
    g_buckets = sorted({max(1, args.gen // 2), args.gen})
    shared = None
    if args.shared_prefix:
        assert args.shared_prefix < args.prompt, \
            "--shared-prefix must leave room for a per-request tail"
        shared = rng.integers(0, vocab, size=(args.shared_prefix,))
    n = args.requests
    prios = [0] * n
    if getattr(args, "priority_classes", 1) > 1:
        prng = np.random.default_rng(args.seed + 2)
        prios = prng.integers(0, args.priority_classes, size=n).tolist()
    arrivals = [0] * n
    if getattr(args, "arrival_rate", 0.0) > 0:
        arng = np.random.default_rng(args.seed + 3)
        if args.trace == "burst":
            bsz = max(1, args.burst_size)
            gap = max(1, round(bsz / args.arrival_rate))
            arrivals = [(i // bsz) * gap for i in range(n)]
        else:
            gaps = arng.exponential(1.0 / args.arrival_rate, size=n)
            arrivals = np.floor(np.cumsum(gaps)).astype(int).tolist()
    reqs = []
    for i in range(n):
        plen = int(rng.choice(p_buckets))
        glen = int(rng.choice(g_buckets))
        if shared is None:
            toks = rng.integers(0, vocab, size=(plen,))
        else:
            plen = max(plen, args.shared_prefix + 1)
            tail = rng.integers(0, vocab, size=(plen - args.shared_prefix,))
            toks = np.concatenate([shared, tail])
        reqs.append({"id": i, "prompt": jnp.asarray(toks, jnp.int32),
                     "gen": glen, "priority": int(prios[i]),
                     "arrival": int(arrivals[i])})
    return reqs


def run_paged(args, cfg) -> dict:
    """Continuous-batching serve loop: CHUNKED paged prefill interleaved
    with decode under a per-step token budget, driven by the SLO-aware
    scheduler (runtime/scheduler.py, DESIGN.md §9/§12).

    Per tick:
      (0) requests whose arrival tick has come join the scheduler queue;
          the optional ``--paranoia N`` sweep runs the pool's full
          conservation + table audit.
      (1) the scheduler places candidates in (priority, PREEMPTED-first,
          arrival, id) order: cache-aware admission when the prefix cache
          is on (the radix tree is walked, the matched block-aligned
          prefix maps by refcount bump, LRU trie-only leaves are evicted
          under pressure — DESIGN.md §10), swap-tier restore for
          preempted-by-swap requests, and PREEMPTION of strictly-lower-
          priority victims when placement refuses (--preemption swap
          evacuates the victim's blocks to host RAM; recompute drops them
          and re-prefills at restore).  A candidate refused even after
          preemption backs off (--retry-backoff) — never a permanent
          refusal.  Admission reserves blocks only; no prompt tokens run.
      (2) spend the step's token budget (``--token-budget``): the decode
          batch first, then prefill chunks of ``--prefill-chunk`` tokens
          from cold slots FCFS while they fit the remainder — a long
          prompt never head-of-line-blocks in-flight decodes (chunked-
          prefill continuous batching).  Under an ITL SLO the scheduler
          shrinks the prefill SHARE of the budget (chunk shapes never
          change — outputs stay bitwise).  The first chunk after a cache
          hit or restore is trimmed onto the GLOBAL chunk grid, so every
          later chunk has exactly the shape the uncached run would have
          used — cached/restored decode is BITWISE identical to the
          uncontended run, not merely close (DESIGN.md §10/§12).  A
          request finishing its prompt inserts its full prompt blocks
          into the trie right away; a RESTORED recompute victim re-seeds
          from the same prefill logits and then TEACHER-FORCES its
          already-delivered tokens through the decode kernel (replay —
          delivered exactly once, re-fed as needed).
      (3) one jitted paged decode step over the decoding slots (cold
          slots' table rows are masked to the null block), then retire
          finished sequences.  ``--fault-rate`` injects deterministic
          worker failures here: the step is discarded, the victim slot is
          requeued through the recompute path, and the heartbeat registry
          notices the missed beat — greedy outputs stay bitwise-identical
          to the unfailed run.

    Re-tracing is bounded: prefill_chunk compiles once per distinct chunk
    size, and chunk sizes are min(--prefill-chunk, remaining prompt) over
    the quantized prompt buckets of :func:`_make_requests`."""
    # one fresh registry per run — back-to-back runs in one process
    # (tests, benchmarks) must never mix counters.  Every subsystem below
    # (pool, scheduler, heartbeats, injector, drafter) writes into it.
    reg = telemetry.MetricsRegistry()
    tracer = (telemetry.Tracer(capacity=args.trace_buffer)
              if args.trace_out else None)
    params = model.init(jax.random.PRNGKey(args.seed), cfg)
    B = args.batch
    max_total = args.prompt + args.gen
    layout = layout_for(B, max_total, block_size=args.page_size,
                        spare_blocks=args.spare_blocks)
    if args.kv_dtype != "fp":
        # capacity accounting (DESIGN.md §11): hold the POOL BYTE BUDGET
        # fixed at what the fp layout would have spent for --batch slots
        # and let the cheaper quantized rows buy more blocks — and with
        # them more concurrent batch slots (~2x at int8 for bf16 configs).
        fp_bytes = model.paged_row_bytes(cfg, "fp")
        q_bytes = model.paged_row_bytes(cfg, args.kv_dtype)
        budget = (layout.num_blocks - 1) * layout.block_size * fp_bytes
        layout, B = layout_for_bytes(budget, q_bytes, max_total,
                                     block_size=args.page_size,
                                     spare_blocks=args.spare_blocks)
    host_blocks = args.host_blocks
    if args.preemption == "swap" and host_blocks == 0:
        host_blocks = layout.num_blocks - 1   # host tier mirrors the pool
    bp = BlockPool(layout, B, host_blocks=host_blocks, metrics=reg)
    prefix = PrefixCache(layout.block_size) if args.prefix_cache else None
    cache = model.init_paged_cache(cfg, layout, kv_dtype=args.kv_dtype)
    pending = deque(sorted(_make_requests(args, cfg.vocab_size),
                           key=lambda r: (r["arrival"], r["id"])))
    n_requests = len(pending)
    chunk = max(1, args.prefill_chunk)
    # auto budget: the whole decode batch plus one prefill chunk per step
    budget = args.token_budget if args.token_budget > 0 else B + chunk

    # KVOps: the scheduler stays device-free; these closures move swap/COW
    # bytes through the LIVE cache pytree (holder — the jitted entries
    # donate and rebind it, so the closures must not capture a stale ref)
    holder = {"cache": cache}

    def _kv_read(ids):
        return model.read_paged_blocks(holder["cache"], ids)

    def _kv_write(ids, rows, start):
        sel = jax.tree.map(lambda r: r[:, start:start + len(ids)], rows)
        holder["cache"] = model.write_paged_blocks(holder["cache"], ids, sel)

    def _kv_copy(src, dst):
        holder["cache"] = model.copy_paged_block(holder["cache"], src, dst)

    sched = scheduler.Scheduler(
        bp, prefix,
        scheduler.KVOps(_kv_read, _kv_write, _kv_copy),
        scheduler.SchedulerConfig(
            preemption=args.preemption, slo_ttft_ms=args.slo_ttft,
            slo_itl_ms=args.slo_itl,
            backoff_cap=max(1, args.retry_backoff)),
        metrics=reg, tracer=tracer)
    injector = (FailureInjector.from_rate(args.fault_rate, metrics=reg)
                if args.fault_rate > 0 else None)
    tick_box = [0]
    # heartbeats on the TICK clock: a beat every tick is alive (gap 1 <=
    # 1.5); the skipped beat of a failure tick (gap 2) trips dead()
    hb = HeartbeatRegistry(timeout_s=1.5, clock=lambda: float(tick_box[0]),
                           metrics=reg)
    WORKER = "decode-worker-0"

    # the cache pytree is DONATED through both jitted entries (as the dense
    # path donates through its scan carry): the pool is updated in place
    # instead of copied per call, keeping admission's peak extra memory at
    # one chunk, not a second pool.
    spec = attn_spec.AttnSpec(mode=args.mode, kv_splits=args.kv_splits,
                              kv_dtype=args.kv_dtype,
                              spec_tokens=args.spec_tokens,
                              spec_draft=args.spec_draft)
    profile_every = args.profile_kernels
    if profile_every:
        # --profile-kernels: (a) route attention through the Pallas kernel
        # entries — the attn_entry choke point wraps THOSE; the XLA
        # reference path is plain functions with nothing to hook — and
        # (b) run the outer step/prefill/verify callables UNJITTED.  Under
        # the default outer jit the attention entries are inlined at trace
        # time (tracer args — the profiler hook must and does skip them);
        # unjitted, every attn_entry still jits and runs its own compiled
        # launch, which the choke-point hook can time with
        # block_until_ready.  Both moves change compilation (not the
        # math), so the bitwise-identity guarantee is stated for DEFAULT
        # sampling (profiling off) only.
        cfg = dataclasses.replace(cfg, use_kernels=True)
        def step_fn(p, c, t, table, lengths):
            return model.decode_step(p, cfg, c, t, None, spec=spec,
                                     cache_layout="paged",
                                     block_table=table, lengths=lengths)

        def prefill_fn(p, cch, t, table, lens):
            return model.prefill_chunk(p, cfg, cch, t, table, lens,
                                       spec=spec)
    else:
        step_fn = jax.jit(lambda p, c, t, table, lengths: model.decode_step(
            p, cfg, c, t, None, spec=spec, cache_layout="paged",
            block_table=table, lengths=lengths),
            donate_argnums=(1,))
        prefill_fn = jax.jit(
            lambda p, cch, t, table, lens: model.prefill_chunk(
                p, cfg, cch, t, table, lens, spec=spec), donate_argnums=(1,))
    # warm the decode step OUTSIDE the timed region (the dense path also
    # compiles before its timer): all slots inactive → the dummy rows land
    # in the reserved null block, so rebinding the returned cache (the
    # donated input is gone) leaves every real pool row untouched.
    table0, lengths0 = bp.device_views()
    logits0, holder["cache"] = step_fn(params, holder["cache"],
                                       jnp.zeros((B,), jnp.int32),
                                       table0, lengths0)
    jax.block_until_ready(logits0)

    # speculative decode (DESIGN.md §14): a host-side drafter proposes
    # k-1 tokens per eligible slot and ONE prefill-shaped verify launch
    # scores all k positions; greedy acceptance keeps the delivered stream
    # bitwise identical to one-at-a-time decode.
    k_max = args.spec_tokens
    verify_fn = drafter = None
    if k_max > 0:
        drafter = spec_decode.make_drafter(args.spec_draft, params,
                                           metrics=reg)
        if profile_every:
            def verify_fn(p, c, t, table, lengths):
                return model.verify_step(p, cfg, c, t, table, lengths,
                                         spec=spec)
        else:
            verify_fn = jax.jit(
                lambda p, c, t, table, lengths: model.verify_step(
                    p, cfg, c, t, table, lengths, spec=spec),
                donate_argnums=(1,))
        # warm the verify pass outside the timer too, with the same all-
        # null masked launch as step_fn: the k dummy rows land in the null
        # block and compile time never lands in t_decode
        logits0, holder["cache"] = verify_fn(params, holder["cache"],
                                             jnp.zeros((B, k_max), jnp.int32),
                                             table0, lengths0)
        jax.block_until_ready(logits0)

    # hot-loop instrument handles: one attribute write per event, no
    # registry lookup inside the tick loop
    c_tokens = reg.counter("serve/decode_tokens")
    c_steps = reg.counter("serve/decode_steps")
    c_spec_steps = reg.counter("serve/spec_verify_steps")
    c_spec_prop = reg.counter("serve/spec_proposed")
    c_spec_acc = reg.counter("serve/spec_accepted")
    c_chunks = reg.counter("serve/prefill_chunks")
    c_inter = reg.counter("serve/interleaved_steps")
    c_pf = reg.counter("serve/prefill_tokens")
    c_replay = reg.counter("serve/replayed_tokens")
    c_restarts = reg.counter("serve/worker_restarts")
    c_ticks = reg.counter("serve/ticks")
    g_queued = reg.gauge("sched/queued")
    g_running = reg.gauge("sched/running")
    t_prefill = 0.0

    # profiler installed AFTER warmup: compile-time launches never land in
    # the records; cleared in the finally so one run can't leak its
    # profiler into the next
    prof = None
    if profile_every:
        prof = telemetry.KernelProfiler(profile_every)
        telemetry.set_profiler(prof)
    t0 = time.perf_counter()
    try:
        while pending or sched.queue or sched.by_slot:
            tick = tick_box[0]
            now = time.perf_counter()
            # ---- (0) arrivals + paranoia sweep + heartbeat bookkeeping
            while pending and pending[0]["arrival"] <= tick:
                req = pending.popleft()
                sched.add(scheduler.Request(
                    id=req["id"], prompt=req["prompt"], gen=req["gen"],
                    priority=req["priority"], arrival=req["arrival"]), now)
            if args.paranoia and tick % args.paranoia == 0:
                bp.audit()
            if hb.dead():                     # missed beat = failure tick
                c_restarts.inc()              # ...worker comes back below
                if tracer is not None:
                    tracer.instant("worker_restart", args={"tick": tick})

            # ---- (1) admission / restore / preemption (scheduler policy)
            sched.admit(tick, now)

            running = sched.running()
            dec = [r for r in running if r.decoding]
            # speculation is restricted to slots with at least k_max
            # deliveries left (uniform-k launches: start + k_max never
            # exceeds the slot's reserved budget exactly when remaining >=
            # k_max) that are not teacher-forcing a restore replay;
            # everything else takes the plain one-token step below
            spec_dec = [r for r in dec
                        if k_max > 0 and not r.replay and r.remaining >= k_max]
            spec_slots = {r.slot for r in spec_dec}
            # decode tokens this step (each spec slot runs k_max verify rows)
            spent = len(dec) + max(0, k_max - 1) * len(spec_dec)
            # ITL SLO: shrink the prefill share of the budget when delivered
            # inter-token latency runs hot (no-op at the default budget split)
            budget_eff = spent + sched.prefill_quota(max(0, budget - spent))

            # ---- (2) prefill chunks from cold slots under the budget
            pf_tokens = 0
            cold = sorted((r for r in running if not r.decoding),
                          key=lambda r: r.admit_seq)
            for r in cold:
                b = r.slot
                plen = r.plen
                # trim the first tail chunk onto the global chunk grid:
                # after a prefix-cache hit (or a restore) at a non-chunk-
                # multiple offset, the next chunk ends at the grid point,
                # so every later chunk has the exact shape the uncached run
                # would have used (bitwise-equal decode, DESIGN.md §10).
                # Uncached (pf_pos % chunk == 0) this is the plain
                # min(chunk, remaining).
                c = min(chunk - r.pf_pos % chunk, plen - r.pf_pos)
                if spent + c > budget_eff and spent > 0:
                    break                     # budget spent — defer chunk
                tp = time.perf_counter()
                ts = tracer.now_us() if tracer is not None else 0.0
                toks_c = r.prompt[None, r.pf_pos:r.pf_pos + c]
                trow = jnp.array(bp.table[b:b + 1])
                lrow = jnp.array(bp.lengths[b:b + 1])
                logits, holder["cache"] = prefill_fn(params, holder["cache"],
                                                     toks_c, trow, lrow)
                jax.block_until_ready(logits)
                t_prefill += time.perf_counter() - tp
                if tracer is not None:
                    tracer.complete("prefill_chunk", ts,
                                    args={"req": r.id, "tokens": c,
                                          "pf_pos": r.pf_pos})
                bp.extend(b, c)
                r.pf_pos += c
                spent += c
                pf_tokens += c
                c_pf.inc(c)
                c_chunks.inc()
                if r.pf_pos == plen:          # prompt done -> start decoding
                    seed = int(jnp.argmax(logits[0, -1]))
                    if r.replay:
                        # restored victim: the re-prefill must re-derive the
                        # first delivered token bit-for-bit (grid invariant)
                        assert seed == r.replay[0], \
                            f"request {r.id}: restore diverged at prefill " \
                            f"(got {seed}, delivered {r.replay[0]})"
                    else:
                        r.cur = seed
                    r.decoding = True
                    if prefix is not None:
                        # cache the prompt's full blocks NOW (not at
                        # release): queued requests share them while this
                        # one decodes
                        prefix.insert(np.asarray(r.prompt), bp.block_ids(b),
                                      bp)

            # ---- (3) one ragged decode step over the decoding slots
            if dec:
                if injector is not None:
                    try:
                        injector.check(tick)
                    except WorkerFailure:
                        # the decode worker died mid-step: its outputs
                        # never land — requeue the victim through the
                        # recompute path and skip the step (no beat →
                        # dead() next tick)
                        victim = max(dec, key=lambda r: r.slot)
                        sched.fail_running(victim.slot, tick)
                        tick_box[0] += 1
                        continue
                # mask cold slots (and, for each launch, the OTHER
                # launch's slots) to the null block: the decode write for
                # them must not land inside a half-prefilled prompt or a
                # live sequence
                plain = [r for r in dec if r.slot not in spec_slots]
                if plain:
                    plain_slots = {r.slot for r in plain}
                    table_m = bp.table.copy()
                    lens_m = bp.lengths.copy()
                    cur_arr = np.zeros((B,), np.int64)
                    for b in range(B):
                        if b not in plain_slots:
                            table_m[b] = 0
                            lens_m[b] = 0
                    for r in plain:
                        cur_arr[r.slot] = r.replay[0] if r.replay else r.cur
                    ts = tracer.now_us() if tracer is not None else 0.0
                    logits, holder["cache"] = step_fn(
                        params, holder["cache"], jnp.array(cur_arr, jnp.int32),
                        jnp.array(table_m), jnp.array(lens_m))
                    nxt = np.asarray(jnp.argmax(logits, axis=-1))
                    if tracer is not None:
                        tracer.complete("decode_step", ts,
                                        args={"slots": len(plain)})
                    c_steps.inc()
                    if pf_tokens:
                        c_inter.inc()

                    # ---- retire / bookkeep (host side)
                    now = time.perf_counter()
                    for r in plain:
                        b = r.slot
                        if r.replay:
                            # teacher-forced replay: the token was already
                            # delivered before preemption — rebuild its KV
                            # row and assert the decode path re-derives the
                            # NEXT token bit-for-bit (the bitwise-restore
                            # guarantee made falsifiable at every replayed
                            # position)
                            fed = r.replay.popleft()
                            bp.append(b)
                            expect = r.replay[0] if r.replay else r.cur
                            assert int(nxt[b]) == int(expect), \
                                f"request {r.id}: replay diverged after " \
                                f"token {fed} (got {int(nxt[b])}, " \
                                f"expected {int(expect)})"
                            c_replay.inc()
                        else:
                            sched.deliver(r, r.cur, now)
                            c_tokens.inc()
                            bp.append(b)
                            r.cur = int(nxt[b])
                            if r.remaining == 0:
                                sched.finish(r)

                if spec_dec:
                    # ---- speculative verify (DESIGN.md §14): draft k-1
                    # tokens per slot from the committed stream, score
                    # [cur, d_1, .., d_{k-1}] in ONE prefill-shaped launch
                    # against the paged pool, accept the longest draft
                    # prefix matching the model's own argmax chain.
                    # Greedy acceptance makes the delivered stream bitwise
                    # identical to one-at-a-time decode whatever the
                    # drafter proposes.
                    table_m = bp.table.copy()
                    lens_m = bp.lengths.copy()
                    tok_arr = np.zeros((B, k_max), np.int64)
                    drafts_by_slot = {}
                    for b in range(B):
                        if b not in spec_slots:
                            table_m[b] = 0
                            lens_m[b] = 0
                    for r in spec_dec:
                        b = r.slot
                        history = np.concatenate([np.asarray(r.prompt),
                                                  np.asarray(r.out + [r.cur],
                                                             np.int64)])
                        ds = (list(drafter(history, k_max - 1))
                              if k_max > 1 else [])
                        drafts_by_slot[b] = ds
                        tok_arr[b] = [r.cur] + ds
                    ts = tracer.now_us() if tracer is not None else 0.0
                    logits, holder["cache"] = verify_fn(
                        params, holder["cache"], jnp.array(tok_arr, jnp.int32),
                        jnp.array(table_m), jnp.array(lens_m))
                    preds = np.asarray(jnp.argmax(logits, axis=-1))  # [B, k]
                    if tracer is not None:
                        tracer.complete("verify_step", ts,
                                        args={"slots": len(spec_dec),
                                              "k": k_max})
                    c_steps.inc()
                    c_spec_steps.inc()
                    if pf_tokens:
                        c_inter.inc()

                    # ---- commit / rewind / deliver (host side)
                    now = time.perf_counter()
                    for r in spec_dec:
                        b = r.slot
                        start = int(bp.lengths[b])
                        # the verify pass appended k_max KV rows on device;
                        # commit them on the host, then rewind the rejected
                        # tail IN PLACE (free_blocks=False — the slot keeps
                        # its full reservation, and the garbage rows sit
                        # past the committed length where no mask ever
                        # reads them until the next launch overwrites them)
                        bp.extend(b, k_max)
                        accepted, nxt_tok = spec_decode.accept_greedy(
                            drafts_by_slot[b], preds[b])
                        bp.truncate(b, start + 1 + accepted,
                                    free_blocks=False)
                        for t in [r.cur] + drafts_by_slot[b][:accepted]:
                            sched.deliver(r, int(t), now)
                            c_tokens.inc()
                        c_spec_prop.inc(len(drafts_by_slot[b]))
                        c_spec_acc.inc(accepted)
                        r.cur = int(nxt_tok)
                        if r.remaining == 0:
                            sched.finish(r)
            # per-tick occupancy gauges: pure reads of pool/scheduler state
            c_ticks.inc()
            bp.observe(reg)
            g_queued.set(len(sched.queue))
            g_running.set(len(sched.by_slot))
            hb.beat(WORKER)
            tick_box[0] += 1
    finally:
        if prof is not None:
            telemetry.set_profiler(None)
    t_total = time.perf_counter() - t0
    t_decode = t_total - t_prefill

    outputs = {rid: r.out for rid, r in sorted(sched.done.items())}
    refused_ids = sched.refused_ids
    prefill_tokens_saved = sched.prefill_tokens_saved
    sstats = sched.stats()
    pstats = prefix.stats() if prefix is not None else None
    snap = reg.snapshot()
    krep = (obs.kernel_report(prof)
            if prof is not None and prof.records else None)
    trace_stats = (obs.write_trace(tracer, args.trace_out)
                   if tracer is not None else None)
    if args.metrics_out:
        obs.write_metrics(
            args.metrics_out, snap,
            config=f"serve:{args.arch}:paged:{args.kv_dtype}")
    tokens_served = c_tokens.value
    first = outputs[0][:16] if outputs.get(0) else []
    for line in obs.summarize_paged(snap, {
            "arch": args.arch, "mode": args.mode, "batch_slots": B,
            "n_requests": n_requests, "page_size": layout.block_size,
            "pool_blocks": layout.num_blocks - 1,
            "host_blocks": host_blocks, "chunk": chunk, "budget": budget,
            "kv_dtype": args.kv_dtype,
            "rescale": softmax_state.default_mode(),
            "prefix": pstats, "preemption": args.preemption,
            "spec_tokens": k_max, "spec_draft": args.spec_draft,
            "t_prefill": t_prefill, "t_decode": t_decode,
            "refusals": len(refused_ids),
            "prefill_tokens_saved": prefill_tokens_saved,
            "sched": sstats, "classes": sched.class_stats(),
            "kernel_report": krep,
            "profile_sampled": prof.sampled if prof is not None else 0,
            "profile_every": profile_every,
            "trace_stats": trace_stats, "metrics_path": args.metrics_out,
            "sample": first}):
        obs.emit(line)
    return {"outputs": outputs, "tokens_served": tokens_served,
            "batch_slots": B, "kv_dtype": args.kv_dtype,
            "pool_blocks": layout.num_blocks - 1,
            "host_blocks": host_blocks,
            "steps": c_steps.value, "refusals": len(refused_ids),
            "prefill_chunks": c_chunks.value,
            "interleaved_steps": c_inter.value,
            "prefill_tokens": c_pf.value,
            "decode_tokens": tokens_served,
            "prefill_tokens_saved": prefill_tokens_saved,
            "replayed_tokens": c_replay.value,
            "worker_restarts": c_restarts.value,
            "prefix": pstats, "sched": sstats,
            "classes": sched.class_stats(),
            "spec": ({"k": k_max, "draft": args.spec_draft,
                      "steps": c_spec_steps.value,
                      "proposed": c_spec_prop.value,
                      "accepted": c_spec_acc.value,
                      "acceptance_rate":
                          c_spec_acc.value / max(c_spec_prop.value, 1)}
                     if k_max > 0 else None),
            "metrics": snap, "kernel_report": krep,
            "t_prefill": t_prefill, "t_decode": t_decode}


def run(args) -> dict:
    # pin the process-wide rescale mode BEFORE any tracing so every kernel
    # entry resolves the same mode (jit_with_rescale keys the cache on it)
    softmax_state.set_default_mode(getattr(args, "rescale",
                                           softmax_state.default_mode()))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.cache_layout == "dense":
        return run_dense(args, cfg)
    return run_paged(args, cfg)


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_r1_671b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4,
                    help="batch slots (paged) / batch size (dense)")
    ap.add_argument("--prompt", type=int, default=64,
                    help="max prompt length (paged draws ragged lengths)")
    ap.add_argument("--gen", type=int, default=32,
                    help="max tokens to generate per request")
    ap.add_argument("--mode", default="etap", choices=["etap", "standard"])
    ap.add_argument("--cache-layout", default="paged",
                    choices=["dense", "paged"],
                    help="KV cache layout; paged = continuous batching "
                         "(the serving default), dense = fixed-batch scan")
    ap.add_argument("--requests", type=int, default=8,
                    help="ragged request count for the paged serve loop")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV block (FlashMLA uses 64)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per paged prefill chunk "
                         "(chunked-prefill continuous batching)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-step token budget shared by the decode batch "
                         "and prefill chunks (0 = batch + prefill-chunk)")
    ap.add_argument("--spare-blocks", type=int, default=0,
                    help="extra pool blocks beyond batch*max_blocks")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="radix-tree prefix cache: share KV blocks of "
                         "common prompt prefixes across requests and skip "
                         "their prefill (--no-prefix-cache disables)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of a common prompt prefix shared by every "
                         "generated request (the prefix-cache workload; "
                         "0 = fully independent prompts)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="priority classes drawn per request (0 = most "
                         "important; 1 = single-tenant FCFS, the default)")
    ap.add_argument("--slo-ttft", type=float, default=0.0,
                    help="time-to-first-token budget in ms: a request past "
                         "it jumps every priority class at admission "
                         "(0 = off; ordering only — outputs stay bitwise)")
    ap.add_argument("--slo-itl", type=float, default=0.0,
                    help="inter-token latency budget in ms: over-budget "
                         "delivered ITL shrinks the prefill share of the "
                         "step token budget (0 = off; bounds chunked-"
                         "prefill interference, outputs stay bitwise)")
    ap.add_argument("--preemption", default="recompute",
                    choices=["swap", "recompute"],
                    help="victim evacuation mode (DESIGN.md §12): swap "
                         "copies written blocks to the host tier and back "
                         "(bitwise trivially); recompute drops them and "
                         "re-prefills + replays at restore (bitwise by the "
                         "chunk-grid invariant + teacher forcing)")
    ap.add_argument("--host-blocks", type=int, default=0,
                    help="host-RAM swap tier size in blocks (0 with "
                         "--preemption swap sizes the tier to mirror the "
                         "device pool)")
    ap.add_argument("--retry-backoff", type=int, default=1,
                    help="max backoff in ticks between admission retries "
                         "(exponential from 1; 1 = retry every tick, the "
                         "pre-scheduler behavior)")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="injected decode-worker failures per tick "
                         "(deterministic schedule via FailureInjector; "
                         "victims requeue through the recompute path and "
                         "outputs stay bitwise; 0 = off)")
    ap.add_argument("--paranoia", type=int, default=0,
                    help="run the BlockPool conservation + full-row table "
                         "audit every N ticks (0 = off; on in tests/CI "
                         "smoke so invariant corruption surfaces at the "
                         "step that caused it)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="request arrivals per scheduler tick (0 = all "
                         "arrive at tick 0)")
    ap.add_argument("--trace", default="uniform",
                    choices=["uniform", "burst"],
                    help="arrival trace shape under --arrival-rate: "
                         "uniform = Poisson gaps; burst = adversarial "
                         "over-admission bursts of --burst-size requests")
    ap.add_argument("--burst-size", type=int, default=4,
                    help="requests per burst for --trace burst")
    ap.add_argument("--spec-tokens", type=int, default=0,
                    help="speculative decode window k (DESIGN.md §14): "
                         "draft k-1 tokens per eligible decode slot and "
                         "score all k positions in ONE prefill-shaped "
                         "verify launch; greedy acceptance keeps outputs "
                         "bitwise identical to one-at-a-time decode "
                         "(0 = off; paged layout only)")
    ap.add_argument("--spec-draft", default="ngram",
                    choices=list(spec_decode.DRAFT_KINDS),
                    help="draft proposer for --spec-tokens: ngram = "
                         "longest-suffix match over the committed stream "
                         "(free, strong on repetitive traces); head = "
                         "embedding-similarity self-draft chain (not "
                         "supported on fp8 pools)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the request-lifecycle + engine-span trace "
                         "as Chrome trace-event JSON (open in "
                         "ui.perfetto.dev or chrome://tracing; DESIGN.md "
                         "§15; paged layout only; outputs stay bitwise)")
    ap.add_argument("--trace-buffer", type=int, default=65536,
                    help="trace ring-buffer capacity in events: overflow "
                         "drops the OLDEST events (counted in the export) "
                         "instead of growing — bounded memory under any "
                         "run length")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the full metrics-registry snapshot as "
                         "schema-versioned JSON (counters/gauges/histogram "
                         "tails + git sha and jax version provenance)")
    ap.add_argument("--profile-kernels", type=int, default=0, metavar="N",
                    help="time every N-th attention-kernel launch at the "
                         "attn_entry choke point (block_until_ready; "
                         "tagged with AttnSpec + geometry, joined against "
                         "the HBM roofline in the summary).  0 = off, the "
                         "default — profiling runs the outer step "
                         "UNJITTED, so use it for kernel attribution, not "
                         "end-to-end throughput (paged layout only)")
    ap.add_argument("--kv-splits", type=int, default=None,
                    help="split-KV count for decode attention "
                         "(default: auto-scheduled)")
    ap.add_argument("--kv-dtype", default=os.environ.get("REPRO_KV_DTYPE",
                                                         "fp"),
                    choices=list(KV_LAYOUTS),
                    help="paged KV storage layout (DESIGN.md §11): fp = "
                         "config dtype; int8/fp8 store per-row quantized "
                         "codes + (scale, zp) and admit ~2x the sequences "
                         "under the same pool byte budget (env default: "
                         "REPRO_KV_DTYPE — the CI int8 leg's hook)")
    ap.add_argument("--rescale", default=os.environ.get("REPRO_RESCALE",
                                                        "amla"),
                    choices=list(softmax_state.MODES),
                    help="online-softmax rescaling mode (DESIGN.md §13): "
                         "amla = deferred power-of-two bias rescaling "
                         "(exponent-add correction, exact in fp); mul = "
                         "textbook multiply-rescale referee (env default: "
                         "REPRO_RESCALE)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args(argv)
    # flag-combo validation: refuse inconsistent speculation configs with
    # a clear CLI error instead of a deep stack trace mid-serve
    if args.spec_tokens < 0:
        ap.error("--spec-tokens must be >= 0")
    if args.spec_tokens > 0 and args.cache_layout == "dense":
        ap.error("--spec-tokens requires --cache-layout paged: the dense "
                 "scan has no block pool to rewind rejected drafts in")
    if args.spec_tokens > 0 and args.spec_draft == "head" \
            and args.kv_dtype == "fp8":
        ap.error("--spec-draft head is not supported with --kv-dtype fp8; "
                 "use --spec-draft ngram")
    if args.trace_buffer < 1:
        ap.error("--trace-buffer must be >= 1")
    if args.profile_kernels < 0:
        ap.error("--profile-kernels must be >= 0")
    if args.cache_layout == "dense" and (args.trace_out
                                         or args.profile_kernels):
        ap.error("--trace-out/--profile-kernels require --cache-layout "
                 "paged: the dense scan is one opaque jitted launch with "
                 "no per-request lifecycle or per-launch entries to record")
    return args


if __name__ == "__main__":
    run(parse_args())
