"""Restartable training driver (deliverable (b): end-to-end example).

Real execution on this host's devices (reduced configs on CPU); the
production mesh is exercised by dryrun.py. Features under test here:
 - deterministic data pipeline (restart-safe)
 - periodic async checkpointing with atomic commit + GC
 - --restart resumes from the latest committed checkpoint
 - failure-injection drill (--fail-at N) for the fault-tolerance test
 - straggler detector fed with per-step wall times

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
        --steps 50 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs import ARCH_IDS, get_config, reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.steps import TrainConfig, make_train_step
from repro.models import model
from repro.optim import optimizers as opt
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatRegistry,
                                           StragglerDetector)


def build_state(cfg, tcfg, rng):
    params = model.init(rng, cfg)
    opt_state = opt.opt_init(tcfg.optimizer, params)
    return params, opt_state


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    data = DataConfig(seed=args.seed, global_batch=args.batch, seq_len=args.seq)
    tcfg = TrainConfig(
        optimizer=opt.OptimizerConfig(lr=args.lr, warmup_steps=5,
                                      total_steps=args.steps),
        n_micro=args.n_micro)
    train_step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    start_step = 0
    params, opt_state = build_state(cfg, tcfg, jax.random.PRNGKey(args.seed))
    if args.restart and args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), start_step = ckpt.restore(
                args.ckpt_dir, latest, (params, opt_state))
            print(f"[train] restored checkpoint step {start_step}")

    injector = FailureInjector(fail_at_steps=(args.fail_at,) if args.fail_at else ())
    heart = HeartbeatRegistry(timeout_s=60)
    strag = StragglerDetector()

    losses = []
    pending_save = None
    for step in range(start_step, args.steps):
        injector.check(step)
        heart.beat("host0")
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, data, step).items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch, step)
        loss = float(metrics["nll"])
        dt = time.perf_counter() - t0
        strag.record("host0", dt)
        losses.append(loss)
        if args.log_every and step % args.log_every == 0:
            print(f"[train] step {step} nll={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = ckpt.save(args.ckpt_dir, step + 1,
                                     (params, opt_state), blocking=False)
            ckpt.gc_old(args.ckpt_dir, keep=3)
    if pending_save is not None:
        pending_save.join()
    return {"losses": losses, "final_step": args.steps,
            "stragglers": strag.stragglers(), "alive": heart.alive()}


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=17)
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the arch for CPU execution")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--restart", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a worker failure at this step (drill)")
    ap.add_argument("--log-every", type=int, default=5)
    return ap.parse_args(argv)


if __name__ == "__main__":
    out = run(parse_args())
    print(f"[train] done: final nll={out['losses'][-1]:.4f}")
