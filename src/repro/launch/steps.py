"""train_step / serve_step factories shared by the dry-run, the trainer and
the server. Gradient accumulation runs as a lax.scan over microbatches
(activation footprint / n_micro); remat is per-block (models.model).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import attn_spec
from repro.models import model
from repro.optim import optimizers as opt


@dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.OptimizerConfig = opt.OptimizerConfig()
    n_micro: int = 1              # gradient-accumulation microbatches
    compress_grads: bool = False  # int8+error-feedback cross-pod reduction


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt_state,
    metrics). Microbatching splits the batch's leading dim into n_micro chunks."""

    def grads_of(params, batch):
        return jax.grad(lambda p: model.loss_fn(p, cfg, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch, step):
        if tcfg.n_micro > 1:
            def reshape(x):
                return x.reshape((tcfg.n_micro, x.shape[0] // tcfg.n_micro)
                                 + x.shape[1:])
            micro = jax.tree.map(reshape, batch)

            def acc_fn(carry, mb):
                g_acc, m_acc = carry
                g, metrics = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype) / tcfg.n_micro, g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b / tcfg.n_micro,
                                     m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            m0 = {k: jnp.zeros((), jnp.float32)
                  for k in ("nll",) + model.AUX_KEYS}
            (grads, metrics), _ = jax.lax.scan(acc_fn, (g0, m0), micro)
        else:
            grads, metrics = grads_of(params, batch)

        if tcfg.compress_grads:
            from repro.optim.compress import compress_tree_for_pod_reduce
            grads = compress_tree_for_pod_reduce(grads)

        grads, gnorm = opt.clip_by_global_norm(grads, tcfg.optimizer.clip_norm)
        params, opt_state = opt.opt_update(tcfg.optimizer, grads, opt_state, params)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg, *, mode: str = "etap"):
    """serve_step(params, cache, tokens, pos) -> (logits, cache): one decode
    token against the existing KV/state cache (the paper's workload)."""
    spec = attn_spec.AttnSpec(mode=mode)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cfg, cache, tokens, pos, spec=spec)
    return serve_step


def make_prefill_step(cfg, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, max_len=max_len)
    return prefill_step
