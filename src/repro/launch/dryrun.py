import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import json
import sys
import time
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config
from repro.data.pipeline import DataConfig, batch_struct
from repro.launch import hlo_analysis, roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import TrainConfig, make_serve_step, make_train_step
from repro.models import model
from repro.optim import optimizers as opt
from repro.sharding import rules

BIG_ARCHES = {"llama4_maverick_400b", "deepseek_r1_671b"}   # adafactor cells


def input_specs(arch: str, shape: str, *, mesh=None):
    """ShapeDtypeStruct stand-ins for every model input of one (arch, shape)
    cell — weak-type-correct, shardable, zero device allocation."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    if cell.is_decode:
        return {
            "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    return batch_struct(cfg, DataConfig(global_batch=B, seq_len=S))


def _batch_shardings(batch, mesh):
    b = rules.batch_axes(mesh)

    def one(leaf):
        spec = [rules._fit(b, leaf.shape[0], mesh)] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, batch)


def _train_config(arch: str, cell) -> TrainConfig:
    ocfg = opt.OptimizerConfig(
        name="adafactor" if arch in BIG_ARCHES else "adamw")
    n_micro = 8 if cell.global_batch >= 64 else 1
    return TrainConfig(optimizer=ocfg, n_micro=n_micro)


def lower_cell(arch: str, shape: str, mesh, *, verbose: bool = True,
               serve_profile: bool = False, n_micro: int = None,
               no_remat: bool = False):
    """Lower + compile one (arch × shape × mesh) cell. Returns report dict.

    Hillclimb knobs (see EXPERIMENTS.md §Perf):
      serve_profile: TP/EP-only weights for decode cells (no FSDP regather)
      n_micro: override the gradient-accumulation depth for train cells
    """
    cfg = get_config(arch)
    cell = SHAPES[shape]
    rng = jax.random.PRNGKey(0)
    t0 = time.time()

    profile = "serve" if (serve_profile and cell.is_decode) else "train"
    if no_remat:
        import dataclasses as _dc0
        cfg = _dc0.replace(cfg, remat=False)
    params_s = jax.eval_shape(functools.partial(model.init, cfg=cfg), rng)
    p_shard = rules.param_shardings(params_s, mesh, profile=profile)

    with jax.set_mesh(mesh):
        if cell.is_decode:
            cache_s = jax.eval_shape(
                lambda: model.init_cache(cfg, cell.global_batch, cell.seq_len))
            c_specs = rules.cache_specs(cache_s, mesh)
            c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
            ins = input_specs(arch, shape)
            tok_shard = _batch_shardings({"t": ins["tokens"]}, mesh)["t"]
            logits_shard = NamedSharding(mesh, P(
                rules._fit(rules.batch_axes(mesh), cell.global_batch, mesh), None))
            step_fn = make_serve_step(cfg)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, c_shard, tok_shard, None),
                out_shardings=(logits_shard, c_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_s, cache_s,
                                   ins["tokens"], ins["pos"])
        else:
            import dataclasses as _dc
            tcfg = _train_config(arch, cell)
            if n_micro is not None:
                tcfg = _dc.replace(tcfg, n_micro=n_micro)
            if cell.kind == "train":
                opt_s = jax.eval_shape(
                    functools.partial(opt.opt_init, tcfg.optimizer), params_s)
                o_specs = rules.opt_state_specs(opt_s, mesh)
                o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs)
                batch = input_specs(arch, shape)
                b_shard = _batch_shardings(batch, mesh)
                step_fn = make_train_step(cfg, tcfg)
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(p_shard, o_shard, b_shard, None),
                    out_shardings=(p_shard, o_shard, None),
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(params_s, opt_s, batch,
                                       jax.ShapeDtypeStruct((), jnp.int32))
            else:   # prefill
                batch = input_specs(arch, shape)
                b_shard = _batch_shardings(batch, mesh)

                def prefill_logits(params, batch):
                    logits, _, cache = model.forward(params, cfg, batch,
                                                     collect_cache=True)
                    return logits[:, -1, :], cache
                jitted = jax.jit(prefill_logits,
                                 in_shardings=(p_shard, b_shard))
                lowered = jitted.lower(params_s, batch)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    analysis = hlo_analysis.analyze(hlo_text)
    terms = roofline.roofline_terms_from_analysis(analysis)
    coll = roofline.CollectiveStats(
        bytes_by_kind=analysis["collective_by_kind"],
        count_by_kind=roofline.parse_collectives(hlo_text).count_by_kind)
    n_active = roofline.active_params(cfg)
    mf = roofline.model_flops(cfg, cell, n_active)
    n_chips = mesh.devices.size
    report = {
        "arch": arch, "shape": shape, "mesh": "x".join(map(str, mesh.devices.shape)),
        "kind": cell.kind,
        **terms,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / terms["hlo_flops"]
        if terms["hlo_flops"] else 0.0,
        "active_params": n_active,
        "total_params": roofline.total_params(cfg),
        "collectives": coll.count_by_kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                report[attr] = int(v)
    if verbose:
        print(f"[dryrun] {arch} × {shape} × {report['mesh']}: "
              f"compute={terms['t_compute']*1e3:.2f}ms "
              f"memory={terms['t_memory']*1e3:.2f}ms "
              f"collective={terms['t_collective']*1e3:.2f}ms "
              f"bottleneck={terms['bottleneck']} "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        if mem is not None:
            print(f"  memory_analysis: args={report.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temps={report.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
        print(f"  cost_analysis: flops/chip={terms['hlo_flops']:.3e} "
              f"bytes/chip={terms['hlo_bytes']:.3e} "
              f"coll_bytes/chip={terms['collective_bytes']:.3e} {coll.count_by_kind}")
    return report


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run + roofline")
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON lines here")
    ap.add_argument("--serve-profile", action="store_true",
                    help="TP/EP-only weights for decode cells (§Perf S1)")
    ap.add_argument("--n-micro", type=int, default=None,
                    help="override gradient-accumulation depth (§Perf)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable per-block remat (§Perf)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    jobs = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        cells = cells_for(cfg)
        if args.shape:
            cells = [c for c in cells if c.name == args.shape]
        for c in cells:
            for mp in meshes:
                jobs.append((arch, c.name, mp))

    failures = []
    for arch, shape, mp in jobs:
        mesh = make_production_mesh(multi_pod=mp)
        try:
            rep = lower_cell(arch, shape, mesh,
                             serve_profile=args.serve_profile,
                             n_micro=args.n_micro, no_remat=args.no_remat)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rep) + "\n")
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((arch, shape, mp, repr(e)[:400]))
            print(f"[dryrun] FAIL {arch} × {shape} × multi={mp}: {e!r}"[:600])
    print(f"\n[dryrun] {len(jobs) - len(failures)}/{len(jobs)} cells OK")
    for f in failures:
        print("  FAIL:", f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
