"""Serve-loop observability front end (DESIGN.md §15).

``runtime/telemetry.py`` owns the primitives (registry / tracer /
profiler); this module owns everything user-facing:

  emit()           the ONE sanctioned stdout chokepoint for serve-loop
                   reporting.  ``benchmarks/lint_prints.py`` fails CI on
                   bare ``print(`` anywhere else in the runtime + serve
                   loop, so every line a serve run shows went through a
                   registry snapshot first — no stat can appear in the
                   human summary without also being in ``--metrics-out``.

  summarize()      renders the ``[serve]`` summary lines from ONE
                   registry snapshot + a context dict of run facts
                   (flags, timings, sample tokens).  The structured
                   snapshot is the source of truth; the text is a view.

  write_metrics()  ``--metrics-out``: the full snapshot as
                   schema-versioned JSON with the same provenance block
                   the BENCH_*.json artifacts carry (git sha, jax
                   version) so CI can archive and diff it.

  write_trace()    ``--trace-out``: Chrome trace-event JSON.  Open in
                   https://ui.perfetto.dev (drag the file in) or
                   chrome://tracing.  Thread 0 is the engine timeline
                   (prefill/decode/verify spans); thread 1000+id is
                   request id's lifecycle instants.

  kernel_report()  joins ``--profile-kernels`` launch timings against the
                   ``launch/roofline.py`` memory-bandwidth model:
                   per-entry achieved GB/s and achieved-vs-roofline
                   fraction (memory-floor time / measured wall time).
"""
from __future__ import annotations

import json
import subprocess

import jax

from repro.launch import roofline
from repro.runtime.telemetry import OBS_SCHEMA_VERSION

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def emit(line: str) -> None:
    """Print one serve-summary line.  The only print site the lint
    allows outside telemetry itself."""
    print(line)


def obs_meta(config: str) -> dict:
    """Provenance block for exported artifacts — the bench_meta pattern
    (benchmarks/run.py) with the telemetry schema version."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    return {"schema_version": OBS_SCHEMA_VERSION, "config": config,
            "git_sha": sha, "jax_version": jax.__version__}


def write_metrics(path: str, snapshot: dict, config: str) -> dict:
    """Write the registry snapshot as schema-versioned JSON."""
    doc = {"meta": obs_meta(config), "metrics": snapshot}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return doc


def write_trace(tracer, path: str) -> dict:
    """Export the ring buffer as Chrome trace-event JSON; returns the
    tracer's export stats (events written / recorded / dropped)."""
    return tracer.export(path)


def _geometry_bytes(geometry: tuple) -> int:
    """HBM traffic floor for one launch: every argument array read once.
    (Outputs and intermediate traffic are not modeled — this is a FLOOR,
    so the reported roofline fraction is an upper bound on achievement.)"""
    total = 0
    for entry in geometry:
        shape, dtype = entry[-2], entry[-1]
        n = 1
        for d in shape:
            n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 2)
    return total


def kernel_report(prof) -> list:
    """Per-(entry, spec, geometry) profile rows joined against the
    roofline memory floor, slowest first."""
    rows = []
    for (name, tag, geometry), (count, total_s) in prof.records.items():
        mean_s = total_s / max(count, 1)
        byts = _geometry_bytes(geometry)
        floor_s = byts / roofline.HBM_BW
        rows.append({
            "entry": name, "spec": tag, "launches": count,
            "mean_us": mean_s * 1e6, "total_ms": total_s * 1e3,
            "arg_bytes": byts,
            "achieved_gbps": byts / max(mean_s, 1e-12) / 1e9,
            "roofline_fraction": floor_s / max(mean_s, 1e-12),
        })
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def kernel_report_lines(rows, sampled: int, sample_every: int) -> list:
    lines = [f"[serve] kernel profile: {sampled} sampled launches "
             f"(every {sample_every}); achieved vs HBM-roofline floor "
             f"({roofline.HBM_BW / 1e9:.0f} GB/s):"]
    for r in rows:
        lines.append(
            f"[serve]   {r['entry']}: {r['launches']}x "
            f"{r['mean_us']:.0f}us/launch, {r['achieved_gbps']:.3g} GB/s, "
            f"{r['roofline_fraction']:.3g} of roofline ({r['spec']})")
    return lines


def _c(snap: dict, name: str) -> int:
    return int(snap["counters"].get(name, 0))


def summarize_paged(snap: dict, ctx: dict) -> list:
    """The ``[serve]`` summary for the paged loop, rendered from the
    registry snapshot.  ``ctx`` carries run facts that are configuration
    or wall-clock, not metrics: flags, timings, sample tokens, the
    per-class stats dict, and the optional kernel report."""
    tokens_served = _c(snap, "serve/decode_tokens")
    steps = _c(snap, "serve/decode_steps")
    prefill_tokens = _c(snap, "serve/prefill_tokens")
    prefill_chunks = _c(snap, "serve/prefill_chunks")
    interleaved = _c(snap, "serve/interleaved_steps")
    t_prefill, t_decode = ctx["t_prefill"], ctx["t_decode"]
    lines = [
        (f"[serve] arch={ctx['arch']} layout=paged mode={ctx['mode']} "
         f"B={ctx['batch_slots']} requests={ctx['n_requests']} "
         f"page={ctx['page_size']} blocks={ctx['pool_blocks']} "
         f"host_blocks={ctx['host_blocks']} chunk={ctx['chunk']} "
         f"budget={ctx['budget']} kv_dtype={ctx['kv_dtype']} "
         f"rescale={ctx['rescale']} prefix_cache="
         f"{'on' if ctx['prefix'] is not None else 'off'} "
         f"preemption={ctx['preemption']} spec_tokens={ctx['spec_tokens']}"),
        (f"[serve] {tokens_served} tokens in {steps} decode steps "
         f"({tokens_served / max(steps, 1):.2f} tokens/step occupancy); "
         f"{prefill_chunks} prefill chunks, {interleaved} steps "
         f"interleaved prefill+decode; prefill {t_prefill*1e3:.1f}ms; "
         f"decode {t_decode*1e3:.1f}ms "
         f"({tokens_served/max(t_decode, 1e-9):.1f} tok/s); "
         f"requests refused at least once: {ctx['refusals']}"),
    ]
    pstats = ctx["prefix"]
    lines.append(
        f"[serve] token split: {prefill_tokens} prefill + {tokens_served} "
        f"decode run, {ctx['prefill_tokens_saved']} prefill skipped"
        + (f"; prefix cache: {pstats['hits']}/{pstats['lookups']} hits "
           f"({pstats['hit_rate']:.0%}), {pstats['cached_blocks']} blocks "
           f"cached, {pstats['evictions']} evicted" if pstats else ""))
    sstats = ctx["sched"]
    if sstats["preemptions"] or sstats["failures"] or sstats["refusals"]:
        lines.append(
            f"[serve] pressure: {sstats['preemptions']} preemptions "
            f"({sstats['preempts_swap']} swap / "
            f"{sstats['preempts_recompute']} recompute), "
            f"{sstats['restores_swap']}+{sstats['restores_recompute']} "
            f"restores, {_c(snap, 'serve/replayed_tokens')} tokens "
            f"replayed, {sstats['refusals']} transient refusals, "
            f"{sstats['failures']} injected failures "
            f"({_c(snap, 'serve/worker_restarts')} worker restarts)")
        for cls, st in ctx["classes"].items():
            lines.append(
                f"[serve]   class {cls}: n={st['n']} "
                f"preempt={st['preemptions']} "
                f"ttft p50/p99 {st['ttft_p50_ms']:.1f}/"
                f"{st['ttft_p99_ms']:.1f}ms itl p50/p99 "
                f"{st['itl_p50_ms']:.2f}/{st['itl_p99_ms']:.2f}ms")
    if ctx["spec_tokens"] > 0:
        proposed = _c(snap, "serve/spec_proposed")
        accepted = _c(snap, "serve/spec_accepted")
        lines.append(
            f"[serve] speculation: k={ctx['spec_tokens']} "
            f"draft={ctx['spec_draft']}; "
            f"{_c(snap, 'serve/spec_verify_steps')} verify launches, "
            f"{accepted}/{proposed} drafts accepted "
            f"({accepted / max(proposed, 1):.0%})")
    if ctx.get("kernel_report"):
        lines.extend(kernel_report_lines(ctx["kernel_report"],
                                         ctx["profile_sampled"],
                                         ctx["profile_every"]))
    for stats, flag in ((ctx.get("trace_stats"), "--trace-out"),
                        (ctx.get("metrics_path"), "--metrics-out")):
        if stats and flag == "--trace-out":
            lines.append(
                f"[serve] trace: {stats['events']} events -> "
                f"{stats['path']} ({stats['dropped']} dropped of "
                f"{stats['recorded']} recorded)")
        elif stats:
            lines.append(f"[serve] metrics snapshot -> {stats}")
    lines.append("[serve] sample generation (request 0): "
                 f"{ctx['sample']}")
    return lines


def summarize_dense(snap: dict, ctx: dict) -> list:
    tokens_served = _c(snap, "serve/decode_tokens")
    t_prefill, t_decode = ctx["t_prefill"], ctx["t_decode"]
    lines = [
        (f"[serve] arch={ctx['arch']} layout=dense mode={ctx['mode']} "
         f"rescale={ctx['rescale']} B={ctx['batch']} "
         f"prompt={ctx['prompt']} gen={ctx['gen']}"),
        (f"[serve] prefill {t_prefill*1e3:.1f}ms; decode "
         f"{t_decode/ctx['gen']*1e3:.2f}ms/token "
         f"({tokens_served/t_decode:.1f} tok/s, {tokens_served} tokens)"),
    ]
    if ctx.get("metrics_path"):
        lines.append(f"[serve] metrics snapshot -> {ctx['metrics_path']}")
    lines.append(f"[serve] sample generation (seq 0): {ctx['sample']}")
    return lines
