"""Version-tolerant JAX API shims.

The codebase is written against the newer mesh-context APIs — ``jax.set_mesh``
/ ``jax.sharding.get_abstract_mesh`` / ``jax.shard_map(..., axis_names=...,
check_vma=...)`` / ``jax.lax.pvary`` — which older installed JAX (0.4.x) does
not expose.  This module maps each of them onto the closest older-API
equivalent (the thread-resources mesh context, ``jax.experimental.shard_map``
with ``auto=``, a no-op ``pvary``), and :func:`install` backfills the handful
of public names that tests and launch scripts call directly on the ``jax``
module, so one tree runs unmodified on either JAX generation.

Everything here is a *lookup-then-fallback*: when the modern API exists it is
used verbatim, so upgrading JAX changes nothing.
"""
from __future__ import annotations

import contextlib
import enum

import jax


# ----------------------------------------------------------- mesh discovery
def get_mesh():
    """The mesh of the current mesh context, or None when no mesh is active.

    New JAX: ``jax.sharding.get_abstract_mesh()`` (set by ``jax.set_mesh``).
    Old JAX: the thread-resources physical mesh (set by ``with mesh:``).
    Both sources are checked on every call so either entry style works.
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        try:
            m = get_am()
        except Exception:  # pragma: no cover - defensive
            m = None
        if m is not None and getattr(m, "axis_names", ()):
            return m
    try:
        from jax._src import mesh as _mesh_lib
    except ImportError:  # pragma: no cover
        return None
    am_fn = getattr(_mesh_lib, "get_abstract_mesh", None)
    if am_fn is not None:
        m = am_fn()
        if getattr(m, "axis_names", ()):
            return m
    tr = getattr(_mesh_lib, "thread_resources", None)
    if tr is not None:
        pm = tr.env.physical_mesh
        if not pm.empty:
            return pm
    return None


def concrete_mesh():
    """Like :func:`get_mesh` but preferring a concrete (device-backed) Mesh —
    what old-JAX shard_map needs as its ``mesh=`` argument."""
    try:
        from jax._src import mesh as _mesh_lib
        pm = _mesh_lib.thread_resources.env.physical_mesh
        if not pm.empty:
            return pm
    except Exception:  # pragma: no cover
        pass
    get_cm = getattr(jax.sharding, "get_concrete_mesh", None)
    if get_cm is not None:
        try:
            m = get_cm()
            if m is not None and getattr(m, "axis_names", ()):
                return m
        except Exception:  # pragma: no cover
            pass
    return get_mesh()


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.set_mesh`` when available, else the legacy ``with mesh:``
    thread-resources context (which :func:`get_mesh` also understands)."""
    native = getattr(jax, "set_mesh", None)
    if native is not None and native is not set_mesh:
        with native(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


class AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on older JAX (where every mesh
    axis is implicitly Auto)."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


_native_make_mesh = jax.make_mesh


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` that tolerates the ``axis_types=`` kwarg missing on
    older JAX (old meshes are Auto-typed already, so dropping it is exact)."""
    try:
        return _native_make_mesh(axis_shapes, axis_names, **kwargs)
    except TypeError:
        kwargs.pop("axis_types", None)
        return _native_make_mesh(axis_shapes, axis_names, **kwargs)


# --------------------------------------------------------------- shard_map
def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
              check=False):
    """``jax.shard_map`` front-end with the modern keyword surface.

    axis_names: the axes the body is *manual* over (others stay automatic).
    check: maps to ``check_vma`` (new) / ``check_rep`` (old).
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        if axis_names:
            kwargs["axis_names"] = set(axis_names)
        return native(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None or not hasattr(mesh, "devices"):
        cm = concrete_mesh()
        mesh = cm if cm is not None else mesh
    auto = frozenset(set(mesh.axis_names) - set(axis_names or mesh.axis_names))
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


def pvary(x, axis_name):
    """``jax.lax.pvary`` (varying-manual-axis marker) — identity on older JAX,
    which has no VMA tracking."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_name) if fn is not None else x


# ------------------------------------------------------------------ pallas
def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new name) / ``pltpu.TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


# ----------------------------------------------------------------- install
def install():
    """Backfill missing public ``jax`` names used directly by tests/scripts.

    Only ever *adds* attributes that the installed JAX lacks — on a modern
    JAX this is a no-op, so behaviour never diverges from upstream.
    """
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = AxisType
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = get_mesh
    try:
        import inspect
        if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
            jax.make_mesh = make_mesh
    except (TypeError, ValueError):  # pragma: no cover
        pass
