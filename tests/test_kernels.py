"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp ref.py oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.etap import etap_decode_xla, standard_decode_xla
from repro.kernels.etap import ops as etap_ops
from repro.kernels.etap.ref import etap_decode_ref
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_prefill.ops import flash_prefill
from repro.kernels.flash_prefill.ref import causal_attention_ref

RNG = np.random.default_rng(0)


def _mk(BG, H, Dk, Dv, S, dtype):
    q = jnp.asarray(RNG.normal(size=(BG, H, Dk)), dtype)
    k = jnp.asarray(RNG.normal(size=(BG, S, Dk)), dtype)
    v = jnp.asarray(RNG.normal(size=(BG, S, Dv)), dtype)
    length = jnp.asarray(RNG.integers(1, S + 1, size=(BG,)), jnp.int32)
    return q, k, v, length


DECODE_SWEEP = [
    # (BG, H, Dk, Dv, S, block)  — includes the paper's MLA geometry (576/512)
    (2, 16, 576, 512, 1024, 256),
    (1, 16, 576, 512, 2048, 512),
    (4, 8, 64, 64, 512, 128),
    (2, 48, 128, 128, 384, 128),
    (3, 4, 128, 96, 160, 64),     # ragged: S % block != 0 (pads + masks)
    (1, 1, 32, 32, 32, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BG,H,Dk,Dv,S,block", DECODE_SWEEP)
def test_etap_kernel_vs_ref(BG, H, Dk, Dv, S, block, dtype):
    q, k, v, length = _mk(BG, H, Dk, Dv, S, dtype)
    scale = Dk ** -0.5
    ref = etap_decode_ref(q, k, v, length, scale=scale)
    out = etap_ops.etap_decode(q, k, v, length, scale=scale, block=block)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BG,H,Dk,Dv,S,block", DECODE_SWEEP)
def test_flash_decode_baseline_vs_ref(BG, H, Dk, Dv, S, block, dtype):
    q, k, v, length = _mk(BG, H, Dk, Dv, S, dtype)
    scale = Dk ** -0.5
    ref = etap_decode_ref(q, k, v, length, scale=scale)
    out = fd_ops.flash_decode(q, k, v, length, scale=scale, block=block)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("S,block", [(512, 128), (768, 256), (96, 32)])
def test_etap_mla_fused_single_stream(S, block):
    """MLA-fused kernel: V = first 512 columns of the latent K stream."""
    q, k, _, length = _mk(2, 16, 576, 512, S, jnp.float32)
    scale = 576 ** -0.5
    ref = etap_decode_ref(q, k, k[..., :512], length, scale=scale)
    out = etap_ops.etap_decode_mla(q, k, 512, length, scale=scale, block=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("B,S,H,K,D,bq,bkv", [
    (2, 128, 4, 2, 32, 32, 32),
    (1, 256, 8, 8, 64, 64, 128),
    (2, 128, 6, 1, 16, 64, 32),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_vs_ref(B, S, H, K, D, bq, bkv, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, K, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, K, D)), dtype)
    scale = D ** -0.5
    out = flash_prefill(q, k, v, scale=scale, bq=bq, bkv=bkv)
    ref = causal_attention_ref(q, k, v, scale=scale)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ------------------------------------------------------ property (hypothesis)
@settings(max_examples=20, deadline=None)
@given(
    BG=st.integers(1, 3), H=st.sampled_from([1, 4, 16]),
    S=st.sampled_from([32, 96, 256]),
    Dk=st.sampled_from([32, 64]), seed=st.integers(0, 2 ** 16),
)
def test_property_etap_equals_standard(BG, H, S, Dk, seed):
    """ETAP (transposed) and the standard pipeline are the same function."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(BG, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BG, S, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BG, S, Dk)), jnp.float32)
    L = jnp.asarray(rng.integers(1, S + 1, size=(BG,)), jnp.int32)
    a = etap_decode_xla(q, k, v, L, scale=0.1, block=32)
    b = standard_decode_xla(q, k, v, L, scale=0.1, block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), shift=st.floats(-50, 50))
def test_property_softmax_shift_invariance(seed, shift):
    """Adding a constant to all scores (q scaled 0) leaves O = mean(V);
    more generally shifting K·qᵀ by a constant can't change the output —
    exercised by scaling q and adding shift·1 via a constant k column."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 16)), jnp.float32)
    base = etap_decode_ref(q, k, v, scale=1.0)
    # shift all logits equally: softmax invariant
    out = etap_decode_ref(q, k, v, scale=1.0, dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_property_batch_permutation_equivariance(seed):
    rng = np.random.default_rng(seed)
    BG, H, S, D = 4, 4, 64, 32
    q = jnp.asarray(rng.normal(size=(BG, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BG, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BG, S, D)), jnp.float32)
    L = jnp.asarray(rng.integers(1, S + 1, size=(BG,)), jnp.int32)
    perm = rng.permutation(BG)
    out = etap_decode_xla(q, k, v, L, scale=0.2, block=32)
    out_p = etap_decode_xla(q[perm], k[perm], v[perm], L[perm], scale=0.2, block=32)
    np.testing.assert_allclose(np.asarray(out)[perm], np.asarray(out_p), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), extra=st.integers(1, 64))
def test_property_length_masking(seed, extra):
    """Appending garbage rows beyond `length` never changes the output."""
    rng = np.random.default_rng(seed)
    BG, H, S, D = 2, 4, 64, 32
    q = jnp.asarray(rng.normal(size=(BG, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BG, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BG, S, D)), jnp.float32)
    L = jnp.asarray(rng.integers(1, S + 1, size=(BG,)), jnp.int32)
    out = etap_decode_xla(q, k, v, L, scale=0.2, block=32)
    k2 = jnp.concatenate([k, 100 * jnp.asarray(
        rng.normal(size=(BG, extra, D)), jnp.float32)], axis=1)
    v2 = jnp.concatenate([v, 100 * jnp.asarray(
        rng.normal(size=(BG, extra, D)), jnp.float32)], axis=1)
    pad = (-(S + extra)) % 32
    k2 = jnp.pad(k2, ((0, 0), (0, pad), (0, 0)))
    v2 = jnp.pad(v2, ((0, 0), (0, pad), (0, 0)))
    out2 = etap_decode_xla(q, k2, v2, L, scale=0.2, block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)


def test_fp64_oracle_rmse_sanity(fp64_oracle):
    """The fp64 oracle exists and fp32 ETAP is close to it (paper Table 1
    methodology; the benchmark reports the actual numbers).  The x64
    enable/restore dance lives in the conftest fixture — tests that need
    the oracle take `fp64_oracle` instead of flipping jax config inline."""
    q, k, v, L = _mk(2, 16, 576, 512, 512, jnp.float32)
    ref64 = fp64_oracle.decode_ref(q, k, v, L, scale=576 ** -0.5)
    out = etap_decode_xla(q, k, v, L, scale=576 ** -0.5, block=128)
    assert fp64_oracle.rmse(out, ref64) < 1e-6


# --------------------------------------------------- selective scan (mamba)
@pytest.mark.parametrize("B,L,D,N,ch,db", [
    (2, 64, 32, 8, 16, 16),
    (1, 100, 48, 4, 32, 16),     # ragged L (padded; y only)
    (2, 256, 128, 16, 64, 64),
])
def test_selective_scan_kernel_vs_ref(B, L, D, N, ch, db):
    from repro.kernels.selective_scan.ops import selective_scan
    from repro.kernels.selective_scan.ref import selective_scan_ref
    rng = np.random.default_rng(3)
    dA = jnp.asarray(rng.uniform(0.5, 1.0, size=(B, L, D, N)), jnp.float32)
    dBx = jnp.asarray(rng.normal(size=(B, L, D, N)) * 0.1, jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    y, h = selective_scan(dA, dBx, c, chunk=ch, d_block=db)
    ref = selective_scan_ref(dA, dBx, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
    if L % ch == 0:
        # final state equals the sequentially-computed one
        def seq(h, t):
            return dA[:, t] * h + dBx[:, t]
        hh = jnp.zeros((B, D, N))
        for t in range(L):
            hh = seq(hh, t)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hh), atol=1e-4)


def test_mamba_model_kernel_path_matches_xla():
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.models import model
    cfg = reduced(get_config("falcon_mamba_7b"))
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    l0, _, _ = model.forward(params, cfg, {"tokens": toks})
    l1, _, _ = model.forward(params, cfg_k, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-4)
