"""Optional-hypothesis shim: property tests run when hypothesis is
installed and are collected-then-skipped (never a collection error) when it
is not.  Import ``given/settings/st`` from here instead of ``hypothesis``."""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """st.* stand-in: any strategy constructor returns None (the stub
        ``given`` never draws from it)."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        def deco(f):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = f.__name__
            skipper.__doc__ = f.__doc__
            return skipper
        return deco
