"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward/train
step on CPU, asserting shapes + finiteness; plus prefill→decode consistency
(which exercises the ETAP decode path end-to-end for every family)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cells_for, get_config, reduced
from repro.models import model
from repro.models.frontend import FRONTEND_DIMS


def _batch(cfg, B, S, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.frontend:
        emb = jax.random.normal(rng, (B, S, FRONTEND_DIMS[cfg.frontend]),
                                jnp.float32)
        return {"embeds": emb, "targets": tokens}
    return {"tokens": tokens}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux, _ = model.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one real gradient step
    loss, metrics = model.loss_fn(params, cfg, batch)
    grads, _ = jax.grad(lambda p: model.loss_fn(p, cfg, batch),
                        has_aux=True)(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(loss)) and np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step(prefill(x[:S]), x[S]) == forward(x)[S] for every family."""
    cfg = reduced(get_config(arch))
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab_size)
    full, _, _ = model.forward(params, cfg, {"tokens": tokens})
    last, cache, pos = model.prefill(params, cfg, {"tokens": tokens[:, :S]},
                                     max_len=S + 4)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, S - 1]),
                               atol=2e-4)
    dec, _ = model.decode_step(params, cfg, cache, tokens[:, S], pos)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, S]),
                               atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_modes_agree(arch):
    """ETAP vs standard decode produce the same logits (paper's equivalence)."""
    cfg = reduced(get_config(arch))
    params = model.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, cfg.vocab_size)
    _, cache, pos = model.prefill(params, cfg, {"tokens": tokens[:, :8]},
                                  max_len=12)
    d_etap, _ = model.decode_step(params, cfg, cache, tokens[:, 8], pos,
                                  mode="etap")
    d_std, _ = model.decode_step(params, cfg, cache, tokens[:, 8], pos,
                                 mode="standard")
    np.testing.assert_allclose(np.asarray(d_etap), np.asarray(d_std), atol=2e-4)


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned hyperparams."""
    spec = {
        "recurrentgemma_9b": dict(num_layers=38, d_model=4096, num_heads=16,
                                  num_kv_heads=1, d_ff=12288, vocab_size=256000),
        "dbrx_132b": dict(num_layers=40, d_model=6144, num_heads=48,
                          num_kv_heads=8, d_ff=10752, vocab_size=100352),
        "llama4_maverick_400b": dict(num_layers=48, d_model=5120, num_heads=40,
                                     num_kv_heads=8, d_ff=8192, vocab_size=202048),
        "qwen3_8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "stablelm_1_6b": dict(num_layers=24, d_model=2048, num_heads=32,
                              num_kv_heads=32, d_ff=5632, vocab_size=100352),
        "granite_20b": dict(num_layers=52, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "smollm_360m": dict(num_layers=32, d_model=960, num_heads=15,
                            num_kv_heads=5, d_ff=2560, vocab_size=49152),
        "musicgen_large": dict(num_layers=48, d_model=2048, num_heads=32,
                               num_kv_heads=32, d_ff=8192, vocab_size=2048),
        "llava_next_34b": dict(num_layers=60, d_model=7168, num_heads=56,
                               num_kv_heads=8, d_ff=20480, vocab_size=64000),
        "falcon_mamba_7b": dict(num_layers=64, d_model=4096, d_ff=0,
                                vocab_size=65024),
    }
    for arch, want in spec.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert get_config("dbrx_132b").moe.num_experts == 16
    assert get_config("dbrx_132b").moe.top_k == 4
    assert get_config("llama4_maverick_400b").moe.num_experts == 128
    assert get_config("llama4_maverick_400b").moe.top_k == 1
    assert get_config("falcon_mamba_7b").ssm.d_state == 16
    assert get_config("deepseek_r1_671b").mla.latent_dim == 576


def test_long_context_cells_only_for_subquadratic():
    """long_500k runs exactly for the SSM/hybrid archs (DESIGN.md skip table)."""
    runs_long = {a for a in ARCH_IDS
                 if any(c.name == "long_500k" for c in cells_for(get_config(a)))}
    assert runs_long == {"recurrentgemma_9b", "falcon_mamba_7b"}


def test_constant_memory_decode_state_for_ssm_and_hybrid():
    """The 500K decode feasibility argument: cache size is O(1) in context
    length for mamba, and O(window) for recurrentgemma."""
    for arch in ("falcon_mamba_7b", "recurrentgemma_9b"):
        cfg = reduced(get_config(arch))
        small = model.init_cache(cfg, batch=1, max_len=64)
        big = model.init_cache(cfg, batch=1, max_len=4096)
        def sz(c):
            return sum(x.size for x in jax.tree.leaves(c))
        assert sz(big) == sz(small)   # window=32 in reduced cfg, both clamp
