"""Properties and acceptance gates for the unified softmax-state API
(kernels/softmax_state.py, DESIGN.md §13).

The bitwise properties run on an EXACT-ARITHMETIC LATTICE: scores drawn
from {0, NEG_INF} and values from small integers.  There every probability
is exactly 1 or 0 in both modes (exp(0) = exp2(0) = 1; the masked branch
underflows to 0), every l is an exact small-integer count, and every acc
entry an exact small-integer sum — so fp32 addition is exact and ANY split
geometry / merge order must finalize BITWISE equal.  A kernel or merge
that sneaks in an extra rounding step (stat downcast, renormalize chain,
mode mix-up between producer and consumer) breaks bitwise equality on the
lattice even when it would pass an allclose on gaussian data.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import softmax_state as ss
from repro.kernels.etap import ops as etap_ops
from repro.kernels.etap.ref import etap_decode_ref, etap_decode_state_ref

MODES = list(ss.MODES)
RNG = np.random.default_rng(0)


def _bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


def _assert_bitwise(a, b, msg=""):
    np.testing.assert_array_equal(_bits(a), _bits(b), err_msg=msg)


def _lattice(S, H, Dv, rng):
    """{0, NEG_INF} scores (row 0 forced live: no fully-masked column) and
    small-integer values — the exact-arithmetic regime."""
    mask = rng.random((S, H)) < 0.5
    mask[0, :] = True
    s = jnp.where(jnp.asarray(mask), 0.0, ss.NEG_INF).astype(jnp.float32)
    v = jnp.asarray(rng.integers(-4, 5, size=(S, Dv)), jnp.float32)
    return s, v


def _state_of(s, v, mode):
    """One whole-context update in the XLA (no-keepdims) orientation:
    stats [H], acc [Dv, H]."""
    H = s.shape[1]
    Dv = v.shape[1]
    return ss.update(ss.init((H,), (Dv, H)), s,
                     lambda p: jnp.einsum("sv,sh->vh", v, p),
                     axis=0, mode=mode)


def _chunks(rng, S):
    """A random contiguous partition of range(S)."""
    cuts = sorted(rng.choice(np.arange(1, S), size=rng.integers(0, S - 1),
                             replace=False).tolist())
    return list(zip([0] + cuts, cuts + [S], strict=True))


# ------------------------------------------------------------ flag plumbing
def test_invalid_mode_rejected():
    with pytest.raises(ValueError):
        ss.resolve("bogus")
    with pytest.raises(ValueError):
        ss.set_default_mode("nope")


def test_default_mode_roundtrip():
    prev = ss.default_mode()
    try:
        ss.set_default_mode("mul")
        assert ss.default_mode() == "mul"
        assert ss.resolve(None) == "mul"
        assert ss.resolve("amla") == "amla"   # explicit beats default
    finally:
        ss.set_default_mode(prev)


def test_jit_with_rescale_no_stale_cache():
    """Flipping the process default between calls of the SAME jitted entry
    must retrace: rescale=None resolves before the jit cache, so the
    post-flip call is bitwise the explicit-mul call, not the cached amla
    trace."""
    q = jnp.asarray(RNG.normal(size=(1, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 128, 16)), jnp.float32)
    kw = dict(scale=32 ** -0.5, block=32)
    o_amla = etap_ops.etap_decode(q, k, v, None, rescale="amla", **kw)
    o_mul = etap_ops.etap_decode(q, k, v, None, rescale="mul", **kw)
    prev = ss.default_mode()
    try:
        ss.set_default_mode("amla")
        _assert_bitwise(etap_ops.etap_decode(q, k, v, None, **kw), o_amla)
        ss.set_default_mode("mul")
        _assert_bitwise(etap_ops.etap_decode(q, k, v, None, **kw), o_mul,
                        "default flip served a stale trace")
    finally:
        ss.set_default_mode(prev)


# ------------------------------------------------------- update recurrence
@pytest.mark.parametrize("mode", MODES)
def test_state_ref_matches_direct_oracle(mode):
    """The blockless init→update→finalize degenerate equals the direct
    softmax definition (both exp domains normalize the bias away)."""
    q = jnp.asarray(RNG.normal(size=(2, 8, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 96, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 96, 48)), jnp.float32)
    length = jnp.asarray([51, 96], jnp.int32)
    ref = etap_decode_ref(q, k, v, length, scale=0.125)
    out = etap_decode_state_ref(q, k, v, length, scale=0.125, rescale=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("mode", MODES)
def test_chunked_update_bitwise_on_lattice(mode):
    """Sequentially chaining update over ANY contiguous chunking finalizes
    bitwise equal to the one-shot update on the exact lattice — the
    correction chain (amla: exact 2^Δ; mul: exp(0)/underflow-0 here)
    injects no rounding."""
    for trial in range(8):
        rng = np.random.default_rng(trial)
        S = int(rng.integers(2, 13))
        s, v = _lattice(S, 3, 2, rng)
        whole = _state_of(s, v, mode)
        state = ss.init((3,), (2, 3))
        for lo, hi in _chunks(rng, S):
            vc = v[lo:hi]
            state = ss.update(state, s[lo:hi],
                              lambda p, vc=vc: jnp.einsum("sv,sh->vh", vc, p),
                              axis=0, mode=mode)
        _assert_bitwise(ss.finalize(state), ss.finalize(whole),
                        f"mode={mode} trial={trial}")


# ----------------------------------------------------------- merge algebra
@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_merge_split_order_invariant_on_lattice(data):
    """DESIGN.md §13's headline property: for any split geometry and any
    merge order — left fold over a permutation, or the stacked
    merge_splits — the finalized output is BITWISE identical to the
    single-pass state, in both rescale modes."""
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    mode = data.draw(st.sampled_from(MODES), label="mode")
    rng = np.random.default_rng(seed)
    S = int(rng.integers(2, 13))
    s, v = _lattice(S, 3, 2, rng)
    want = ss.finalize(_state_of(s, v, mode))

    parts = [_state_of(s[lo:hi], v[lo:hi], mode)
             for lo, hi in _chunks(rng, S)]
    order = rng.permutation(len(parts))
    folded = parts[order[0]]
    for i in order[1:]:
        folded = ss.merge(folded, parts[int(i)], mode=mode)
    _assert_bitwise(ss.finalize(folded), want,
                    f"fold order {order.tolist()} diverged (mode={mode})")

    stacked = [jnp.stack(x) for x in zip(*parts, strict=True)]
    m_g, l_g, acc_g = ss.merge_splits(*stacked, axis=0, mode=mode,
                                      expand=lambda w: w[:, None, :])
    _assert_bitwise(ss.finalize((m_g, l_g, acc_g)), want,
                    f"merge_splits diverged (mode={mode})")


@pytest.mark.parametrize("mode", MODES)
def test_merge_associative_commutative_on_lattice(mode):
    for trial in range(8):
        rng = np.random.default_rng(100 + trial)
        states = [_state_of(*_lattice(int(rng.integers(1, 9)), 3, 2, rng),
                            mode) for _ in range(3)]
        a, b, c = states
        ab_c = ss.merge(ss.merge(a, b, mode=mode), c, mode=mode)
        a_bc = ss.merge(a, ss.merge(b, c, mode=mode), mode=mode)
        for x, y in zip(ab_c, a_bc, strict=True):
            _assert_bitwise(x, y, f"associativity, mode={mode}")
        ba = ss.merge(b, a, mode=mode)
        for x, y in zip(ss.merge(a, b, mode=mode), ba, strict=True):
            _assert_bitwise(x, y, f"commutativity, mode={mode}")


@pytest.mark.parametrize("mode", MODES)
def test_merge_split_order_allclose_general_floats(mode):
    """Off the lattice bitwise equality is not promised (p additions round
    differently per geometry) — but any split geometry must still agree to
    fp32 roundoff."""
    rng = np.random.default_rng(7)
    S, H, Dv = 96, 4, 8
    s = jnp.asarray(rng.normal(scale=3.0, size=(S, H)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(S, Dv)), jnp.float32)
    want = np.asarray(ss.finalize(_state_of(s, v, mode)))
    for trial in range(4):
        trng = np.random.default_rng(trial)
        parts = [_state_of(s[lo:hi], v[lo:hi], mode)
                 for lo, hi in _chunks(trng, S)]
        folded = parts[0]
        for p in parts[1:]:
            folded = ss.merge(folded, p, mode=mode)
        np.testing.assert_allclose(np.asarray(ss.finalize(folded)), want,
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", MODES)
def test_masked_split_drops_out(mode):
    """A fully-masked split (m = NEG_INF) merges as an exact no-op even
    when its accumulator holds garbage: the weight underflows to 0."""
    rng = np.random.default_rng(3)
    real = _state_of(*_lattice(8, 3, 2, rng), mode)
    junk = (jnp.full((3,), ss.NEG_INF, jnp.float32),
            jnp.zeros((3,), jnp.float32),
            jnp.full((2, 3), 1e20, jnp.float32))
    for merged in (ss.merge(real, junk, mode=mode),
                   ss.merge(junk, real, mode=mode)):
        for x, y in zip(merged, real, strict=True):
            _assert_bitwise(x, y, f"masked split leaked, mode={mode}")


def test_merge_upcasts_half_precision_stats():
    """The PR 5 bf16-combine-stats guard lives INSIDE the merges: half
    inputs come out as fp32 math, bitwise the fp32-input result."""
    rng = np.random.default_rng(4)
    parts = [_state_of(*_lattice(8, 3, 2, rng), "amla") for _ in range(2)]
    stacked = [jnp.stack(x) for x in zip(*parts, strict=True)]
    want = ss.merge_splits(*stacked, axis=0, mode="amla",
                           expand=lambda w: w[:, None, :])
    half = [x.astype(jnp.bfloat16) for x in stacked]
    got = ss.merge_splits(*half, axis=0, mode="amla",
                          expand=lambda w: w[:, None, :])
    for x, y in zip(got, want, strict=True):
        assert x.dtype == jnp.float32
        # lattice stats are small integers: exactly representable in bf16,
        # so the upcast path must reproduce the fp32 result bitwise
        _assert_bitwise(x, y, "bf16 stats changed the merge")
    w = ss.merge_weights(half[0][0], want[0], mode="amla")
    assert w.dtype == jnp.float32


# ------------------------------------------------------- RMSE acceptance
@pytest.mark.parametrize("mode", MODES)
def test_rmse_fp32_vs_fp64_oracle(mode, fp64_oracle):
    """fp32 kernels stay within the paper-methodology RMSE budget vs the
    fp64 oracle in BOTH rescale modes (amla must not cost accuracy)."""
    q = jnp.asarray(RNG.normal(size=(2, 16, 576)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 1024, 576)), jnp.float32)
    v = k[..., :512]
    length = jnp.asarray([515, 1024], jnp.int32)
    ref = fp64_oracle.decode_ref(q, k, v, length, scale=576 ** -0.5)
    out = etap_ops.etap_decode(q, k, v, length, scale=576 ** -0.5,
                               block=256, rescale=mode)
    assert fp64_oracle.rmse(out, ref) <= 1e-5


@pytest.mark.parametrize("mode", MODES)
def test_rmse_quant_vs_fp64_oracle(mode, fp64_oracle):
    """Quantized decode holds the PR 5 acceptance values against the fp64
    oracle in both rescale modes (int8 <= 6.12e-4, fp8 <= 2.22e-3 — the
    PR 5 BENCH_quant measurements, bench geometry, same seed): deferred
    rescaling must not cost quantized accuracy."""
    from repro.runtime import paged_cache as pcache
    B, H, DIM, DV, S, page = 2, 16, 576, 512, 1024, 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, DIM)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(B, S, DIM)), jnp.float32)
    lengths = np.asarray([S // 2 + 3, S])
    layout = pcache.layout_for(B, S, block_size=page)
    pool, bp = pcache.dense_to_paged(kv, lengths, layout)
    table, lens = bp.device_views()
    ref = fp64_oracle.decode_ref(q, kv, kv[..., :DV], jnp.asarray(lengths),
                                 scale=DIM ** -0.5)
    budgets = {"int8": 6.12e-4, "fp8": 2.22e-3}
    for kvd in ["int8"] + (["fp8"] if pcache.HAS_FP8 else []):
        codes, sz = pcache.quantize_pool(pool, kvd)
        out = etap_ops.etap_decode_mla_paged(q, codes, DV, table, lens,
                                             scale=DIM ** -0.5, kv_sz=sz,
                                             rescale=mode)
        rmse = fp64_oracle.rmse(out, ref)
        assert rmse <= budgets[kvd], (kvd, mode, rmse)


# ------------------------------------------------------- AttnSpec API
def test_attn_spec_shim_bitwise_equals_spec():
    """The legacy-keyword shim and the AttnSpec call are the SAME call:
    bitwise-equal outputs, with the shim announcing its deprecation."""
    from repro.core import attn_spec
    q = jnp.asarray(RNG.normal(size=(1, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 128, 16)), jnp.float32)
    with pytest.warns(DeprecationWarning):
        legacy = etap_ops.etap_decode(q, k, v, None, scale=32 ** -0.5,
                                      block=32, rescale="mul")
    spec = etap_ops.etap_decode(
        q, k, v, None,
        spec=attn_spec.AttnSpec(scale=32 ** -0.5, block=32, rescale="mul"))
    _assert_bitwise(spec, legacy, "shim and spec paths diverged")
    # the n_splits -> kv_splits alias maps through the same shim
    with pytest.warns(DeprecationWarning):
        leg2 = etap_ops.etap_decode_splitkv(q, k, v, None, scale=32 ** -0.5,
                                            block=32, n_splits=2)
    spec2 = etap_ops.etap_decode_splitkv(
        q, k, v, None,
        spec=attn_spec.AttnSpec(scale=32 ** -0.5, block=32, kv_splits=2))
    _assert_bitwise(spec2, leg2, "n_splits alias diverged")


def test_attn_spec_rejects_spec_plus_legacy():
    from repro.core import attn_spec
    q = jnp.asarray(RNG.normal(size=(1, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 16, 32)), jnp.float32)
    with pytest.raises(TypeError):
        etap_ops.etap_decode(q, k, k[..., :16], None,
                             spec=attn_spec.AttnSpec(scale=32 ** -0.5),
                             block=16)


def test_attn_spec_unused_field_flip_does_not_retrace():
    """Extends the stale-cache flip test above to the WHOLE spec: fields a
    jitted entry does not use (spec_tokens, spec_draft, kv_dtype for a
    dense decode) are projected to defaults BEFORE the jit cache, so
    flipping them is a cache hit — while flipping a field the trace DOES
    depend on (block) retraces."""
    from repro.core import attn_spec
    q = jnp.asarray(RNG.normal(size=(1, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 128, 16)), jnp.float32)
    jfn = etap_ops.etap_decode.__wrapped_jit__
    assert "spec_tokens" not in etap_ops.etap_decode.__attn_uses__
    base = attn_spec.AttnSpec(scale=32 ** -0.5, block=32, rescale="mul")
    etap_ops.etap_decode(q, k, v, None, spec=base)
    n0 = jfn._cache_size()
    for flip in (base.replace(spec_tokens=4),
                 base.replace(spec_draft="head"),
                 base.replace(kv_dtype="int8"),
                 base.replace(kv_splits=8)):   # also unused by etap_decode
        etap_ops.etap_decode(q, k, v, None, spec=flip)
    assert jfn._cache_size() == n0, "unused spec field forced a retrace"
    etap_ops.etap_decode(q, k, v, None, spec=base.replace(block=64))
    assert jfn._cache_size() == n0 + 1, "used field must retrace"
