# NOTE: deliberately empty of XLA device-count flags — smoke tests and
# benches must see the host's real (single) device; only launch/dryrun.py
# and explicit subprocess tests request 512/8 fake devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


@pytest.fixture
def fp64_oracle():
    """Paper Table-1 RMSE methodology as a reusable fixture: enables x64
    for the test body, yields a namespace of fp64 reference builders plus
    the RMSE estimator, and restores the x64 flag on teardown (so the rest
    of the suite keeps fp32 weak-typing).  Used by the kernel sanity test
    and the softmax-state acceptance gates (fp <= 1e-5, int8 <= 6.1e-4,
    fp8 <= 2.2e-3 — the DESIGN.md §13 budgets)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)

    class Oracle:
        @staticmethod
        def decode_ref(q, k, v, length=None, *, scale):
            """fp64 direct-definition decode oracle (inputs upcast)."""
            from repro.kernels.etap.ref import etap_decode_ref
            q64, k64, v64 = (jnp.asarray(a, jnp.float64) for a in (q, k, v))
            return etap_decode_ref(q64, k64, v64, length, scale=scale,
                                   dtype=jnp.float64)

        @staticmethod
        def quant_decode_ref(q, k_codes, k_sz, v_codes, v_sz, length=None,
                             *, scale, dv=0):
            """fp64 oracle for quantized KV: dequantize with the runtime
            definition, then the fp64 direct oracle (same dequant-then-
            slice order as the kernels)."""
            from repro.kernels.etap.ref import dequantize
            k = dequantize(k_codes, k_sz)
            v = dequantize(v_codes, v_sz) if v_codes is not None \
                else k[..., :dv]
            return Oracle.decode_ref(q, k, v, length, scale=scale)

        @staticmethod
        def rmse(out, ref):
            err = np.asarray(out, np.float64) - np.asarray(ref, np.float64)
            return float(np.sqrt(np.mean(err ** 2)))

    try:
        yield Oracle
    finally:
        jax.config.update("jax_enable_x64", prev)
