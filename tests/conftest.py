# NOTE: deliberately empty of XLA device-count flags — smoke tests and
# benches must see the host's real (single) device; only launch/dryrun.py
# and explicit subprocess tests request 512/8 fake devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
