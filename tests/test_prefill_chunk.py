"""Chunked paged ETAP prefill validation (DESIGN.md §9): kernel/XLA paths
vs a dense causally-masked oracle, model-level equivalence of ANY chunking
against single-shot prefill (block-aligned, unaligned, 1-chunk — the
acceptance grid — plus a hypothesis property over random partitions), and
the token-budget serve loop interleaving prefill chunks with decode steps.
All Pallas runs are interpret=True on CPU; tolerances match test_paged.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs import get_config, reduced
from repro.core.etap import etap_prefill_xla, prefill_attention_paged
from repro.kernels.etap import ops as etap_ops
from repro.models import model
from repro.runtime import paged_cache as pc

RNG = np.random.default_rng(23)


def _ref_prefill(q, k, v, start):
    """fp64 dense oracle: row softmax over key positions <= start + c."""
    q64 = np.asarray(q, np.float64)
    k64 = np.asarray(k, np.float64)
    v64 = np.asarray(v, np.float64)
    B, Cq, H, Dk = q64.shape
    S = k64.shape[1]
    scale = Dk ** -0.5
    out = np.zeros((B, Cq, H, v64.shape[-1]))
    kpos = np.arange(S)
    for b in range(B):
        s = np.einsum("chd,sd->chs", q64[b], k64[b]) * scale
        for c in range(Cq):
            live = kpos <= start[b] + c
            sc = s[c][:, live]
            p = np.exp(sc - sc.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[b, c] = p @ v64[b][live]
    return out


def _rmse(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.sqrt(np.mean((a - b) ** 2)))


# starts deliberately straddle both page sizes: mid-page, page-aligned,
# one past a 64 boundary — the chunk always crosses at least one boundary.
S, CQ = 192, 11
STARTS = [5, 64, 65]


@pytest.mark.parametrize("page", [16, 64])
def test_prefill_kernel_paths_vs_ref(page):
    B, H, Dk, Dv = 3, 4, 32, 24
    q = jnp.asarray(RNG.normal(size=(B, CQ, H, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Dv)), jnp.float32)
    start = jnp.asarray(STARTS, jnp.int32)
    ref = _ref_prefill(q, k, v, STARTS)
    scale = Dk ** -0.5
    # dense XLA twin
    assert _rmse(etap_prefill_xla(q, k, v, start, scale=scale, block=page),
                 ref) <= 1e-4
    # paged kernel + gather-XLA fallback on the same pool
    total = [s + CQ for s in STARTS]
    k_pool, bp = pc.dense_to_paged(k, total, pc.layout_for(B, S, page))
    v_pool, _ = pc.dense_to_paged(v, total, pc.layout_for(B, S, page))
    table, _ = bp.device_views()
    out_k = etap_ops.etap_prefill_paged(q, k_pool, v_pool, table, start,
                                        scale=scale)
    assert _rmse(out_k, ref) <= 1e-4
    out_x = prefill_attention_paged(q, k_pool, v_pool, table, start,
                                    scale=scale, use_kernels=False)
    assert _rmse(out_x, ref) <= 1e-4


def test_prefill_kernel_mla_fused_vs_ref():
    """Single latent pool, V = pool[..., :dv] — the paper's serving path."""
    B, H, D, dv, page = 2, 4, 48, 32, 16
    q = jnp.asarray(RNG.normal(size=(B, CQ, H, D)), jnp.float32)
    kv = jnp.asarray(RNG.normal(size=(B, S, D)), jnp.float32)
    start = jnp.asarray(STARTS[:B], jnp.int32)
    ref = _ref_prefill(q, kv, np.asarray(kv)[..., :dv], STARTS[:B])
    total = [s + CQ for s in STARTS[:B]]
    pool, bp = pc.dense_to_paged(kv, total, pc.layout_for(B, S, page))
    table, _ = bp.device_views()
    out = etap_ops.etap_prefill_mla_paged(q, pool, dv, table, start,
                                          scale=D ** -0.5)
    assert _rmse(out, ref) <= 1e-4


def test_prefill_kernel_shuffled_table():
    """The prefill kernel must follow the TABLE, not physical pool order."""
    page, n, H, Dk = 16, 6, 4, 32
    Sl = n * page
    q = jnp.asarray(RNG.normal(size=(1, CQ, H, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, Sl, Dk)), jnp.float32)
    start = jnp.asarray([Sl - CQ], jnp.int32)
    perm = RNG.permutation(np.arange(1, n + 1)).astype(np.int32)
    pool = np.zeros((n + 1, page, Dk), np.float32)
    pool[perm] = np.asarray(k[0]).reshape(n, page, Dk)
    out = etap_ops.etap_prefill_mla_paged(q, jnp.asarray(pool), Dk,
                                          perm[None, :], start,
                                          scale=Dk ** -0.5)
    ref = _ref_prefill(q, k, np.asarray(k), [Sl - CQ])
    assert _rmse(out, ref) <= 1e-4


# ------------------------------------------------- model-level equivalence
@pytest.fixture(scope="module")
def mla_model():
    """Reduced deepseek (the paper's arch) without MoE: the top-k router is
    discontinuous, so float noise between the naive single-shot and
    absorbed chunked attention orders could flip an expert at a near-tie
    gate — an O(1e-2) logit jump unrelated to the chunking under test."""
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    return cfg, model.init(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def gqa_model():
    cfg = reduced(get_config("qwen3_8b"), kv_heads=2)
    return cfg, model.init(jax.random.PRNGKey(0), cfg)


def _check_chunking(cfg, params, toks, chunks, *, page=8, atol=2e-4):
    """Chunked paged prefill over `chunks` must match the single-shot dense
    forward at EVERY prompt position (a strictly stronger check than the
    final logits single-shot model.prefill returns)."""
    B, P = toks.shape
    assert sum(chunks) == P
    full, _, _ = model.forward(params, cfg, {"tokens": toks})
    layout = pc.layout_for(B, P, block_size=page)
    bp = pc.BlockPool(layout, B)
    paged = model.init_paged_cache(cfg, layout)
    for b in range(B):
        assert bp.admit(0, P) == b           # cold admission, blocks only
    lgs, lo = [], 0
    for c in chunks:
        table, lengths = bp.device_views()
        lg, paged = model.prefill_chunk(params, cfg, paged,
                                        toks[:, lo:lo + c], table, lengths)
        lgs.append(lg)
        lo += c
        for b in range(B):
            bp.extend(b, c)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(lgs, axis=1)),
                               np.asarray(full), atol=atol, rtol=1e-3)


# the acceptance grid: block-aligned, unaligned (straddles 8-token pages),
# and the whole prompt in one chunk
CHUNKINGS = {"aligned": (8, 8, 8), "unaligned": (5, 11, 8), "one": (24,)}


@pytest.mark.parametrize("chunks", CHUNKINGS.values(), ids=CHUNKINGS.keys())
def test_chunked_prefill_matches_single_shot_mla(mla_model, chunks):
    cfg, params = mla_model
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    _check_chunking(cfg, params, toks, chunks)


def test_chunked_prefill_matches_single_shot_mla_kernels(mla_model):
    """Same contract through the Pallas prefill kernel (interpret mode)."""
    cfg, params = mla_model
    cfg = dataclasses.replace(cfg, use_kernels=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    _check_chunking(cfg, params, toks, (5, 11, 8))


def test_chunked_prefill_matches_single_shot_gqa(gqa_model):
    """The generic grouped-query attention stack pages + chunks too."""
    cfg, params = gqa_model
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0,
                              cfg.vocab_size)
    _check_chunking(cfg, params, toks, (7, 9, 8))


def _random_partition(rng, total):
    chunks = []
    while total:
        c = int(rng.integers(1, total + 1))
        chunks.append(c)
        total -= c
    return tuple(chunks)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(data=st.data())
    def test_chunked_prefill_any_partition(mla_model, data):
        """Property: ANY partition of the prompt — chunk sizes free to be
        indivisible by (and straddle) the pool block size — matches the
        single-shot forward."""
        cfg, params = mla_model
        P = data.draw(st.integers(min_value=4, max_value=32), label="P")
        chunks, left = [], P
        while left:
            c = data.draw(st.integers(min_value=1, max_value=left),
                          label="chunk")
            chunks.append(c)
            left -= c
        toks = jax.random.randint(jax.random.PRNGKey(P), (1, P), 0,
                                  cfg.vocab_size)
        _check_chunking(cfg, params, toks, tuple(chunks))
else:
    def test_chunked_prefill_any_partition(mla_model):
        """Deterministic stand-in for the hypothesis property (keeps the
        tier-1 skip count flat when hypothesis is absent): seeded random
        partitions of random prompt lengths."""
        cfg, params = mla_model
        rng = np.random.default_rng(7)
        for _ in range(4):
            P = int(rng.integers(4, 33))
            toks = jax.random.randint(jax.random.PRNGKey(P), (1, P), 0,
                                      cfg.vocab_size)
            _check_chunking(cfg, params, toks, _random_partition(rng, P))


def test_chunked_prefill_moe_self_consistent():
    """MoE stacks chunk too — through the serving (dropless) router, which
    deliberately diverges from single-shot prefill's capacity-dropped
    training router (see model._block_prefill_chunk).  The oracle here is
    therefore SELF-consistency: many chunks vs one chunk, both through
    prefill_chunk, must agree.  Tolerance is loose because the top-k gate
    is discontinuous — float noise between the two chunkings' attention
    summation orders may flip an expert at a near-tie (an O(1e-2) jump);
    wiring bugs are O(1)."""
    cfg = reduced(get_config("deepseek_r1_671b"))
    assert cfg.moe is not None
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 24), 0,
                              cfg.vocab_size)

    def run(chunks):
        layout = pc.layout_for(2, 24, block_size=8)
        bp = pc.BlockPool(layout, 2)
        paged = model.init_paged_cache(cfg, layout)
        for b in range(2):
            assert bp.admit(0, 24) == b
        lgs, lo = [], 0
        for c in chunks:
            table, lengths = bp.device_views()
            lg, paged = model.prefill_chunk(params, cfg, paged,
                                            toks[:, lo:lo + c], table,
                                            lengths)
            lgs.append(lg)
            lo += c
            for b in range(2):
                bp.extend(b, c)
        return np.asarray(jnp.concatenate(lgs, axis=1))

    np.testing.assert_allclose(run((5, 11, 8)), run((24,)), atol=5e-2,
                               rtol=0)


# ------------------------------------------------------------- serve loop
def test_serve_interleaves_prefill_chunks_with_decode():
    """Under a small per-step token budget the scheduler must (a) split
    admission prefill into chunks and (b) keep decoding in the same steps —
    no admission stall — while every request still gets exactly its
    budgeted tokens."""
    from repro.launch import serve

    args = serve.parse_args([
        "--reduced", "--batch", "2", "--prompt", "32", "--gen", "8",
        "--requests", "4", "--page-size", "16", "--cache-layout", "paged",
        "--prefill-chunk", "8", "--token-budget", "10"])
    res = serve.run(args)
    assert len(res["outputs"]) == 4          # every request served
    gens = {i: len(v) for i, v in res["outputs"].items()}
    assert res["tokens_served"] == sum(gens.values())
    assert all(n in (4, 8) for n in gens.values())  # the two gen buckets
    # prompts (16/24/32 tokens) must have run as multiple 8-token chunks...
    assert res["prefill_chunks"] >= 2 * len(res["outputs"])
    # ...and decode steps must have been taken in the same scheduler steps
    # as prefill chunks — the no-head-of-line-blocking acceptance check.
    assert res["interleaved_steps"] > 0
    assert res["steps"] >= max(gens.values())
