"""Sharding rules + miniature-mesh integration (8 fake CPU devices in a
subprocess so the main pytest process keeps its single-device view)."""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.sharding import rules


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_fit_divisibility():
    m = FakeMesh()
    assert rules._fit("model", 64, m) == "model"
    assert rules._fit("model", 15, m) is None
    assert rules._fit(("pod", "data"), 8, m) is None    # 8 % 16 != 0, no pod
    assert rules._fit(("data", "model"), 256, m) == ("data", "model")


def test_param_specs_cover_all_archs():
    m = FakeMesh()
    for arch in ("qwen3_8b", "dbrx_132b", "deepseek_r1_671b",
                 "falcon_mamba_7b", "recurrentgemma_9b", "smollm_360m"):
        cfg = get_config(arch)
        import functools
        ps = jax.eval_shape(functools.partial(model.init, cfg=cfg),
                            jax.random.PRNGKey(0))
        specs = rules.param_specs(ps, m)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        shapes = jax.tree_util.tree_flatten_with_path(ps)[0]
        n_model_sharded = 0
        for (kp, spec), (_, leaf) in zip(flat, shapes, strict=True):
            # every spec entry must divide its dim (validity invariant)
            for i, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                size = int(np.prod([m.shape[a] for a in axes]))
                assert leaf.shape[i] % size == 0, (arch, kp, spec, leaf.shape)
            if any("model" in str(e) for e in spec if e):
                n_model_sharded += 1
        assert n_model_sharded > 0, arch      # TP actually engaged


def test_moe_expert_weights_expert_parallel():
    m = FakeMesh()
    cfg = get_config("dbrx_132b")
    import functools
    ps = jax.eval_shape(functools.partial(model.init, cfg=cfg),
                        jax.random.PRNGKey(0))
    specs = rules.param_specs(ps, m)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    moe_specs = [s for kp, s in flat if "w_gate" in str(kp) and
                 len(s) == 4]                 # [L, E, D, F]
    assert moe_specs and all(s[1] == "model" for s in moe_specs)


def test_batch_axes():
    class M3(FakeMesh):
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    assert rules.batch_axes(FakeMesh()) == ("data",)
    assert rules.batch_axes(M3()) == ("pod", "data")


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, functools
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.models import model
    from repro.sharding import rules
    from repro.launch.steps import TrainConfig, make_train_step
    from repro.optim import optimizers as opt

    cfg = reduced(get_config("%s"))
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    params = model.init(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(optimizer=opt.OptimizerConfig(lr=1e-3))
    opt_state = opt.opt_init(tcfg.optimizer, params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg.vocab_size)}
    # unsharded reference
    p_ref, _, m_ref = make_train_step(cfg, tcfg)(params, opt_state, batch, 0)
    # sharded run
    with jax.set_mesh(mesh):
        p_shard = rules.param_shardings(params, mesh)
        o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               rules.opt_state_specs(opt_state, mesh))
        step = jax.jit(make_train_step(cfg, tcfg),
                       in_shardings=(p_shard, o_shard, None, None),
                       out_shardings=(p_shard, o_shard, None))
        p_new, o_new, m = step(params, opt_state, batch, 0)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                  b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_new),
                            strict=True))
    print(json.dumps({"nll": float(m["nll"]), "nll_ref": float(m_ref["nll"]),
                      "max_param_diff": d}))
""")


@pytest.mark.parametrize("arch", ["smollm_360m", "dbrx_132b",
                                  "deepseek_r1_671b", "falcon_mamba_7b",
                                  "recurrentgemma_9b"])
def test_sharded_train_step_matches_unsharded(arch):
    """One sharded train step on a (2,4) fake mesh == the unsharded step."""
    out = subprocess.run([sys.executable, "-c", _SUBPROC % arch],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["nll"] - res["nll_ref"]) < 1e-3, res
    assert res["max_param_diff"] < 5e-2, res


def test_seq_sharded_decode_primitives_subprocess():
    """Sequence-sharded decode primitives (shard_map over model) match the
    single-device ETAP reference bit-tight, for both MLA and GQA forms."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, functools
        import numpy as np
        import jax, jax.numpy as jnp
        import repro.sharding.rules as rules
        rules.SEQ_SHARD_MIN_S = 64        # engage sharding at test scale
        from repro.core import etap
        from repro.kernels.etap.ref import etap_decode_ref

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        rng = np.random.default_rng(0)
        B, H, L, S, dv = 2, 8, 48, 128, 32
        q = jnp.asarray(rng.normal(size=(B, H, L)), jnp.float32)
        cache = jnp.asarray(rng.normal(size=(B, S, L)), jnp.float32)
        new_row = jnp.asarray(rng.normal(size=(B, L)), jnp.float32)
        pos = jnp.asarray(77, jnp.int32)
        ref_cache = cache.at[:, 77].set(new_row)
        ref = etap_decode_ref(q, ref_cache, ref_cache[..., :dv],
                              jnp.full((B,), 78, jnp.int32), scale=0.1)
        with jax.set_mesh(mesh):
            o, c2 = jax.jit(functools.partial(
                etap.seq_sharded_decode, dv=dv, scale=0.1, block=16))(
                q, cache, new_row, pos)
        d_mla = float(jnp.max(jnp.abs(o - ref)))
        d_cache = float(jnp.max(jnp.abs(c2 - ref_cache)))

        # GQA form
        K, G, hd = 4, 2, 16
        q4 = jnp.asarray(rng.normal(size=(B, K, G, hd)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
        nk = jnp.asarray(rng.normal(size=(B, K, hd)), jnp.float32)
        nv = jnp.asarray(rng.normal(size=(B, K, hd)), jnp.float32)
        kr = kc.at[:, 77].set(nk); vr = vc.at[:, 77].set(nv)
        ref_g = etap.gqa_decode_xla(q4, kr, vr,
                                    jnp.full((B,), 78, jnp.int32),
                                    scale=0.1, block=16)
        with jax.set_mesh(mesh):
            og, kc2, vc2 = jax.jit(functools.partial(
                etap.seq_sharded_gqa_decode, scale=0.1, block=16))(
                q4, kc, vc, nk, nv, pos)
        d_gqa = float(jnp.max(jnp.abs(og - ref_g)))
        print(json.dumps({"d_mla": d_mla, "d_cache": d_cache,
                          "d_gqa": d_gqa}))
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["d_mla"] < 1e-4 and res["d_cache"] == 0.0 \
        and res["d_gqa"] < 1e-4, res
