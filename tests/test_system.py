"""End-to-end system behaviour: training convergence, microbatch-accumulation
equivalence, optimizers, ETAP core equivalences inside the full model, data
pipeline determinism, and a miniature sharded end-to-end run."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.steps import TrainConfig, make_train_step
from repro.models import model
from repro.optim import optimizers as opt


def _setup(arch="smollm_360m", **tkw):
    cfg = reduced(get_config(arch))
    tcfg = TrainConfig(optimizer=opt.OptimizerConfig(
        lr=1e-3, warmup_steps=2, total_steps=50, **tkw.pop("okw", {})), **tkw)
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt.opt_init(tcfg.optimizer, params)
    return cfg, tcfg, params, opt_state


def test_training_reduces_loss_on_learnable_data():
    """Train on a tiny fixed batch — loss must drop hard (memorization)."""
    cfg, tcfg, params, opt_state = _setup()
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size)}
    first = None
    for s in range(30):
        params, opt_state, m = step_fn(params, opt_state, batch, s)
        first = first or float(m["nll"])
    assert float(m["nll"]) < first * 0.7, (first, float(m["nll"]))


def test_grad_accumulation_equivalence():
    """n_micro=4 must equal n_micro=1 up to accumulation-dtype rounding."""
    cfg, _, params, _ = _setup()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (8, 32),
                                          0, cfg.vocab_size)}
    outs = {}
    for n in (1, 4):
        tcfg = TrainConfig(optimizer=opt.OptimizerConfig(lr=1e-3),
                           n_micro=n)
        opt_state = opt.opt_init(tcfg.optimizer, params)
        p2, _, m = make_train_step(cfg, tcfg)(params, opt_state, batch, 0)
        outs[n] = (jax.tree.leaves(p2), float(m["nll"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-2
    for a, b in zip(outs[1][0], outs[4][0], strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-2)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_step_and_descend(name):
    cfg, tcfg, params, _ = _setup(okw={"name": name})
    ocfg = tcfg.optimizer
    state = opt.opt_init(ocfg, params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    l0, _ = model.loss_fn(params, cfg, batch)
    for _ in range(10):
        grads, _ = jax.grad(lambda p: model.loss_fn(p, cfg, batch),
                            has_aux=True)(params)
        grads, _ = opt.clip_by_global_norm(grads, 1.0)
        params, state = opt.opt_update(ocfg, grads, state, params)
    l1, _ = model.loss_fn(params, cfg, batch)
    assert float(l1) < float(l0)


def test_adafactor_state_is_factored():
    cfg, _, params, _ = _setup(okw={"name": "adafactor",
                                    "min_dim_size_to_factor": 8})
    state = opt.opt_init(opt.OptimizerConfig(name="adafactor",
                                             min_dim_size_to_factor=8), params)
    leaves = jax.tree_util.tree_flatten_with_path(state["v"])[0]
    assert any("vr" in "".join(str(p) for p in kp) for kp, _ in leaves)
    # factored stats are ~sqrt the size of the full moment
    n_v = sum(l.size for _, l in leaves)
    n_p = sum(l.size for l in jax.tree.leaves(params))
    assert n_v < 0.5 * n_p


def test_data_pipeline_determinism_and_sharding_split():
    cfg = reduced(get_config("qwen3_8b"))
    d = DataConfig(seed=5, global_batch=8, seq_len=16)
    a = make_batch(cfg, d, step=3)
    b = make_batch(cfg, d, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, d, step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host shard [2,6) equals the slice of the global batch (restart safety)
    part = make_batch(cfg, d, step=3, lo=2, hi=6)
    np.testing.assert_array_equal(part["tokens"], a["tokens"][2:6])


def test_loss_fn_matches_manual_cross_entropy():
    cfg, _, params, _ = _setup("stablelm_1_6b")
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                                cfg.vocab_size)
    logits, _, _ = model.forward(params, cfg, {"tokens": tokens})
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    manual = -np.mean([lp[b, t, tokens[b, t + 1]]
                       for b in range(2) for t in range(11)])
    loss, metrics = model.loss_fn(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(float(metrics["nll"]), manual, rtol=1e-5)


def test_layer_grouping_plans():
    """Grouping compiles each distinct block body once (DESIGN.md §3)."""
    g = model.layer_groups(get_config("qwen3_8b"))
    assert len(g) == 1 and g[0]["n"] == 36
    g = model.layer_groups(get_config("recurrentgemma_9b"))
    assert g[0]["sigs"] == [("rglru", False), ("rglru", False), ("attn", False)]
    assert g[0]["n"] == 12 and len(g) == 3          # 12 cycles + 2 tail layers
    g = model.layer_groups(get_config("deepseek_r1_671b"))
    assert [x["n"] for x in g] == [3, 58]           # dense prefix + MoE stack
    total = sum(x["n"] * len(x["sigs"]) for x in g)
    assert total == 61


def test_etap_used_in_model_decode_matches_kernel():
    """The model's decode path and the Pallas kernel agree on real MLA
    activations (not just synthetic tensors)."""
    import dataclasses
    cfg = reduced(get_config("deepseek_r1_671b"))
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    _, cache, pos = model.prefill(params, cfg, {"tokens": tokens[:, :8]},
                                  max_len=16)
    d_xla, _ = model.decode_step(params, cfg, cache, tokens[:, 8], pos)
    d_krn, _ = model.decode_step(params, cfg_k, cache, tokens[:, 8], pos)
    np.testing.assert_allclose(np.asarray(d_xla), np.asarray(d_krn), atol=2e-4)
