"""Tests for the unified invariant analyzer (``repro.analysis``, DESIGN.md §16).

Four layers, mirroring the acceptance criteria:

* fixture corpus — every rule fires on its ``bad_*`` snippets (flagged
  lines must exactly match the ``# REPRO0xx`` annotations when present)
  and stays silent on the ``good_*`` rewrites;
* mechanics — per-rule ``# noqa`` suppression, fingerprint baseline
  round-trip (including line-number drift), ``--diff`` on a synthetic
  git tree, stable exit codes;
* self-test — an injected violation in a temp copy of the real
  ``kernels/`` tree fails the run (the PR 5 bf16-stat bug pattern),
  mirroring ``check_regression.py``'s injected-slowdown self-test;
* integration — the real tree is clean, the deprecation shims still
  run, and ``retrace.SPEC_FIELDS`` tracks the AttnSpec dataclass.
"""
import pathlib
import re
import subprocess
import sys

import pytest

from repro.analysis import cli, core, retrace

FIXDIR = pathlib.Path(__file__).resolve().parent / "analysis_fixtures"
RULE_IDS = tuple(f"REPRO00{i}" for i in range(1, 10))

# Pretend repo-relative path each rule's fixtures are scanned under.
# Rules are scoped (dtype-flow only reads kernels/, bare-print only the
# runtime), so a fixture must land inside the right scope to exercise
# its rule — and the good twin must stay silent *at the same path*.
RULE_REL = {
    "REPRO001": "src/repro/kernels/fixture.py",
    "REPRO002": "src/repro/kernels/fixture.py",
    "REPRO003": "src/repro/core/fixture.py",
    "REPRO004": "src/repro/core/fixture.py",
    "REPRO005": "src/repro/core/fixture.py",
    "REPRO006": "src/repro/core/fixture.py",
    "REPRO007": "tests/fixture.py",
    "REPRO008": "src/repro/launch/fixture.py",
    "REPRO009": "src/repro/runtime/fixture.py",
}

_ANNOT = re.compile(r"#\s*(REPRO\d{3})")


def _scan(text, rel, select=None):
    sf = core.SourceFile(rel, text)
    return cli.run_passes(sf, select)


def _cases(kind):
    out = []
    for rule in RULE_IDS:
        for path in sorted((FIXDIR / rule).glob(f"{kind}_*.py")):
            out.append(pytest.param(rule, path, id=f"{rule}/{path.name}"))
    return out


def test_fixture_corpus_is_complete():
    for rule in RULE_IDS:
        d = FIXDIR / rule
        assert list(d.glob("bad_*.py")), f"{rule}: no bad fixture"
        assert list(d.glob("good_*.py")), f"{rule}: no good fixture"


@pytest.mark.parametrize("rule, path", _cases("bad"))
def test_bad_fixture_fires(rule, path):
    text = path.read_text()
    kept, _ = _scan(text, RULE_REL[rule])
    assert kept, f"{path.name}: rule {rule} did not fire"
    assert {f.rule for f in kept} == {rule}, (
        f"{path.name}: unexpected cross-rule findings {kept}")
    annotated = {i for i, ln in enumerate(text.splitlines(), 1)
                 if _ANNOT.search(ln)}
    if annotated:     # annotations pin the exact flagged lines
        assert {f.line for f in kept} == annotated


@pytest.mark.parametrize("rule, path", _cases("good"))
def test_good_fixture_is_silent(rule, path):
    kept, _ = _scan(path.read_text(), RULE_REL[rule])
    assert kept == [], [f.render() for f in kept]


@pytest.mark.parametrize("rule, path", _cases("bad"))
def test_noqa_suppresses_exactly_that_rule(rule, path):
    text = path.read_text()
    kept, _ = _scan(text, RULE_REL[rule])
    lines = text.splitlines()
    for f in kept:
        lines[f.line - 1] += f"  # noqa: {f.rule}"
    kept2, suppressed = _scan("\n".join(lines), RULE_REL[rule])
    assert kept2 == []
    assert suppressed == len(kept)


def test_bare_noqa_does_not_suppress():
    text = "def f(reg):\n    print('tok/s')  # noqa\n"
    kept, suppressed = _scan(text, RULE_REL["REPRO009"])
    assert [f.rule for f in kept] == ["REPRO009"]
    assert suppressed == 0


def test_parse_error_is_a_finding():
    kept, _ = _scan("def f(:\n", "src/repro/kernels/broken.py")
    assert [f.rule for f in kept] == ["REPRO000"]


def test_select_restricts_rules():
    text = (FIXDIR / "REPRO009" / "bad_bare_print.py").read_text()
    kept, _ = _scan(text, RULE_REL["REPRO009"], select={"REPRO007"})
    assert kept == []


def test_spec_fields_track_attn_spec_dataclass():
    import dataclasses

    from repro.core.attn_spec import AttnSpec
    assert retrace.SPEC_FIELDS == tuple(
        f.name for f in dataclasses.fields(AttnSpec)), (
        "AttnSpec grew/lost a field: update retrace.SPEC_FIELDS so the "
        "uses= completeness check (REPRO004) keeps seeing every field")


# ---------------------------------------------------------------- runner

BAD_PRINT = ("def tick(sched):\n"
             "    print('tok/s', sched.tok_s)\n")
CLEAN = "def tick(sched, reg):\n    reg.gauge('serve/tok_s').set(1.0)\n"


def _mk(root, rel, text):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def test_runner_flags_and_baseline_roundtrip(tmp_path, capsys):
    bad = _mk(tmp_path, "src/repro/runtime/stats.py", BAD_PRINT)
    assert cli.main(["--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "REPRO009" in out and "stats.py:2" in out
    assert "--diff" in out          # failure text advertises the fast path

    # grandfather, then the same tree is green
    assert cli.main(["--root", str(tmp_path), "--write-baseline"]) == 0
    assert (tmp_path / "analysis_baseline.txt").is_file()
    capsys.readouterr()
    assert cli.main(["--root", str(tmp_path)]) == 0
    assert "1 baselined" in capsys.readouterr().out

    # fingerprints key on line CONTENT: drift the line number, stay green
    bad.write_text("# a comment pushed above\n# another\n" + BAD_PRINT)
    assert cli.main(["--root", str(tmp_path)]) == 0

    # fixing the finding makes the entry stale (reported, still exit 0)
    bad.write_text(CLEAN)
    capsys.readouterr()
    assert cli.main(["--root", str(tmp_path)]) == 0
    assert "stale baseline entry" in capsys.readouterr().out


def test_new_finding_not_masked_by_baseline(tmp_path, capsys):
    _mk(tmp_path, "src/repro/runtime/stats.py", BAD_PRINT)
    assert cli.main(["--root", str(tmp_path), "--write-baseline"]) == 0
    _mk(tmp_path, "src/repro/runtime/fresh.py", BAD_PRINT.replace(
        "tok/s", "p99"))
    capsys.readouterr()
    assert cli.main(["--root", str(tmp_path)]) == 1
    assert "fresh.py" in capsys.readouterr().out


def _git(root, *args):
    subprocess.run(
        ["git", "-C", str(root), "-c", "user.email=t@t", "-c", "user.name=t",
         *args], check=True, capture_output=True)


def test_diff_mode_scans_only_changed_files(tmp_path, capsys):
    ok = _mk(tmp_path, "src/repro/runtime/ok.py", CLEAN)
    _mk(tmp_path, "src/repro/runtime/old_bad.py", BAD_PRINT)
    _git(tmp_path, "init", "-q")
    _git(tmp_path, "add", "-A")
    _git(tmp_path, "commit", "-qm", "seed")

    # full mode sees the committed violation; --diff scans nothing
    assert cli.main(["--root", str(tmp_path)]) == 1
    capsys.readouterr()
    assert cli.main(["--root", str(tmp_path), "--diff"]) == 0

    # a modified tracked file and a new untracked file are both scanned;
    # the unchanged committed violation stays out of the diff scan
    ok.write_text(CLEAN + "\n\ndef leak():\n    print('oops')\n")
    _mk(tmp_path, "src/repro/runtime/new_bad.py", BAD_PRINT)
    capsys.readouterr()
    assert cli.main(["--root", str(tmp_path), "--diff"]) == 1
    out = capsys.readouterr().out
    assert "ok.py" in out and "new_bad.py" in out
    assert "old_bad.py" not in out


def test_explicit_paths_restrict_scan(tmp_path, capsys):
    _mk(tmp_path, "src/repro/runtime/a.py", BAD_PRINT)
    _mk(tmp_path, "src/repro/runtime/b.py", BAD_PRINT)
    capsys.readouterr()
    assert cli.main(["--root", str(tmp_path),
                     "src/repro/runtime/b.py"]) == 1
    out = capsys.readouterr().out
    assert "b.py" in out and "a.py" not in out


def test_exit_codes_are_stable(tmp_path):
    assert cli.main(["--no-such-flag"]) == 2
    assert cli.main(["--select", "NOPE"]) == 2
    assert cli.main(["--root", str(tmp_path / "missing")]) == 2
    assert cli.main(["--list-rules"]) == 0


def test_list_rules_covers_catalog(capsys):
    assert cli.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("REPRO000",) + RULE_IDS:
        assert rule in out


# ------------------------------------------------------------- self-test

def test_injected_kernel_violation_fails_the_run(tmp_path, capsys):
    """Mirror of check_regression.py's injected-slowdown self-test: copy
    the real kernels/ tree, confirm it is green, inject the PR 5 bug
    pattern (bf16 running max) into etap.py, confirm the analyzer is the
    thing that would have caught it."""
    import shutil
    src = core.REPO / "src" / "repro" / "kernels"
    dst = tmp_path / "src" / "repro" / "kernels"
    shutil.copytree(src, dst)
    assert cli.main(["--root", str(tmp_path)]) == 0

    etap = dst / "etap" / "etap.py"
    etap.write_text(etap.read_text() + (
        "\n\ndef _injected_combine(m, l, acc):\n"
        "    m = m.astype(jnp.bfloat16)\n"
        "    return m, l, acc\n"))
    capsys.readouterr()
    assert cli.main(["--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "REPRO001" in out and "etap/etap.py" in out


# ----------------------------------------------------------- integration

def test_real_tree_is_clean(capsys):
    assert cli.main([]) == 0
    assert "repro.analysis: ok" in capsys.readouterr().out


@pytest.mark.parametrize("shim, rule", [
    ("lint_softmax.py", "REPRO002"),
    ("lint_attn_spec.py", "REPRO006"),
    ("lint_prints.py", "REPRO009"),
])
def test_deprecation_shims_still_run(shim, rule):
    proc = subprocess.run(
        [sys.executable, str(core.REPO / "benchmarks" / shim)],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "deprecated" in proc.stderr
    assert rule in proc.stderr
