# An attn_entry that reads spec fields its uses= tuple does not declare:
# canonicalize() resets them to defaults before the trace runs, so the
# caller's setting silently does nothing.
from repro.core import attn_spec


@attn_spec.attn_entry(uses=("block", "interpret"))
def decode(q, k, v, length, *, spec):
    block = min(spec.block, 64)
    if spec.kv_splits:                  # REPRO004: kv_splits not in uses=
        block = block // spec.kv_splits
    return q * spec.scale, block, spec.rescale   # REPRO004: rescale too
