# Every spec field the body reads is declared in uses= (scale is always
# kept by project(), so it needs no declaration).
from repro.core import attn_spec


@attn_spec.attn_entry(uses=("block", "kv_splits", "interpret", "rescale"))
def decode(q, k, v, length, *, spec):
    block = min(spec.block, 64)
    if spec.kv_splits:
        block = block // spec.kv_splits
    return q * spec.scale, block, spec.rescale, spec.replace()
