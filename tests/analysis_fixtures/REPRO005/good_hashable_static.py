# Hashable statics (tuples, strings, ints) key the jit cache fine; the
# same literals are also fine in NON-static positions.
import jax


def f(x, shape, dims=None):
    return x


jfn = jax.jit(f, static_argnames=("shape",))


def call_sites(x):
    a = jfn(x, shape=(4, 4))           # tuple: hashable
    b = jfn(x, shape="auto")
    c = jfn(x, shape=(4, 4), dims=[0, 1])   # dims is not static
    return a, b, c
