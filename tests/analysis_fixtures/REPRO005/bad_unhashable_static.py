# Unhashable literals in static jit positions: the cache key must hash,
# so these raise at call time — but only on the branches that execute.
import jax


def f(x, shape, dims=None):
    return x


jfn = jax.jit(f, static_argnames=("shape",))
gfn = jax.jit(f, static_argnums=(1,))


def call_sites(x):
    a = jfn(x, shape=[4, 4])           # REPRO005: list as static kwarg
    b = gfn(x, [4, 4])                 # REPRO005: list in static position
    c = jfn(x, shape={"h": 4})         # REPRO005: dict as static kwarg
    return a, b, c
