# The sanctioned shape: stats live in fp32, only the finalized OUTPUT is
# cast back to the query dtype.
import jax.numpy as jnp

from repro.kernels import softmax_state


def combine_partials_fp32(m, l, acc, o_ref):
    state = softmax_state.merge_splits(
        m.astype(jnp.float32), l.astype(jnp.float32),
        acc.astype(jnp.float32), axis=1, mode="amla")
    # casting the finalize() RESULT is fine: it is the attention output,
    # not state
    return softmax_state.finalize(state).T.astype(o_ref.dtype)


def init_state_fp32(H, Dv):
    m = jnp.full((1, H), -1e30, dtype=jnp.float32)
    l = jnp.zeros((1, H), jnp.float32)
    acc = jnp.zeros((Dv, H), dtype=jnp.float32)
    state = softmax_state.init((1, H), (Dv, H), dtype=jnp.float32)
    return m, l, acc, state
