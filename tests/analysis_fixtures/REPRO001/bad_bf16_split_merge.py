# The PR 5 bug, reconstructed: split online-softmax statistics merged in
# bf16.  The exp/sum followed the input dtype, and near-tie maxima lost
# accumulated mass.  Scanned as if it lived under src/repro/kernels/.
import jax.numpy as jnp


def combine_partials_bf16(m, l, acc):
    # stats arrive fp32 from the partial kernels; the cast narrows them
    m = m.astype(jnp.bfloat16)              # REPRO001: cast
    m_new = jnp.max(m, axis=1)
    w = jnp.exp(m - m_new[:, None])
    l_new = jnp.sum(l * w, axis=1)
    return m_new, l_new, acc


def init_state_narrow(H, Dv):
    m = jnp.full((1, H), -1e30, dtype=jnp.bfloat16)   # REPRO001: born narrow
    l = jnp.zeros((1, H), jnp.float16)                # REPRO001: born narrow
    acc = jnp.zeros((Dv, H), jnp.float32)
    return m, l, acc


def init_via_api_narrow(softmax_state, H, Dv):
    state = softmax_state.init((1, H), (Dv, H), dtype=jnp.float16)  # REPRO001
    return state
