# The serve-loop rebind idiom: the donated argument is rebound from the
# call's result in the same statement, so later reads see the new buffer.
import jax


def serve(params, cache, model, tokens):
    step = jax.jit(model.decode, donate_argnums=(1,))
    for t in tokens:
        logits, cache = step(params, cache, t)     # rebound each call
    return logits, cache.sum()


def serve_holder(params, holder, model, tokens):
    step = jax.jit(model.decode, donate_argnums=(1,))
    logits, holder["cache"] = step(params, holder["cache"], tokens)
    return logits, holder["cache"]


def serve_last_use(params, cache, model, tokens):
    step = jax.jit(model.decode, donate_argnums=(1,))
    return step(params, cache, tokens)             # nothing reads it after
