# Donated buffers read after the jitted call: the array aliases freed
# memory — stale bytes or a runtime error, never a type error.
import jax


def serve(params, cache, model, tokens):
    step = jax.jit(model.decode, donate_argnums=(1,))
    logits = step(params, cache, tokens)       # cache donated, NOT rebound
    stale = cache.sum()                        # REPRO008
    return logits, stale


def serve_holder(params, holder, model, tokens):
    step = jax.jit(model.decode, donate_argnums=(1,))
    logits = step(params, holder["cache"], tokens)   # donated, not rebound
    return logits, holder["cache"]             # REPRO008
