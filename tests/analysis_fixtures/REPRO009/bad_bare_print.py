# A stat printed straight from the runtime: it escaped the registry —
# not exportable, not assertable, drifts from the rendered summary.
def tick_summary(sched, reg):
    print(f"tok/s {sched.tok_s:.1f}")          # REPRO009
    for cls, p99 in sched.tails().items():
        print(cls, p99)                        # REPRO009
