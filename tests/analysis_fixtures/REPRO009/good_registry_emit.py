# The sanctioned sink: numbers land in the MetricsRegistry, summaries
# render from the snapshot through obs.emit.
def tick_summary(sched, reg, obs):
    reg.gauge("serve/tok_s").set(sched.tok_s)
    for cls, p99 in sched.tails().items():
        reg.histogram(f"sched/class{cls}/itl_ms").record(p99)
    obs.emit(obs.summarize_paged(reg.snapshot()))
