# The sanctioned shapes: immutable module constants trace fine, and
# mutable defaults are resolved BEFORE the jit-cache lookup (the
# jit_with_rescale contract).
import jax

SCALE = 0.125                # immutable: safe to close over
CONFIG = {"mode": "amla"}


@jax.jit
def decode_step(x):
    return x * SCALE         # constant closure: no hazard


def entry(x, mode=None):
    mode = CONFIG["mode"] if mode is None else mode   # resolved pre-cache

    @jax.jit
    def body(x, mode_):
        return x if mode_ else -x

    return body(x, mode == "amla")


def shadowed(x):
    CONFIG = {"local": True}          # local shadows the module dict
    return jax.jit(lambda y: y)(x), CONFIG
