# Jitted functions reading mutable module state: the value is baked into
# the trace at first call; mutating the dict later serves a stale trace.
import jax

CONFIG = {"scale": 1.0}
TABLE = [1, 2, 3]


@jax.jit
def decode_step(x):
    return x * CONFIG["scale"]          # REPRO003: traced dict read


def make_step():
    return jax.jit(lambda x: x + TABLE[0])   # REPRO003: traced list read


@jax.jit
def bump(x):
    global COUNTER                      # REPRO003: global in a jitted body
    return x
