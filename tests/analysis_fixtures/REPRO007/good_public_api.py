# The public inspection surface: conservation and invariant tests read
# through accessors, mutation goes through the lifecycle API.
def leak_check(bp, trie, slot):
    bp.check_conservation()
    free = bp.free_ids()
    chain = bp.block_ids(slot)
    budget = bp.budget(slot)
    cached = trie.cached_block_ids()
    pinned = trie.stats()["pinned_blocks"]
    return free, chain, budget, cached, pinned


def rebuild(trie, bp, slot):
    bp.truncate(slot, 0)
    bp.release(slot)
    bp.audit()
