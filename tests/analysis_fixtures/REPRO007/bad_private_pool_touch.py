# Test/benchmark code peeking at BlockPool/PrefixCache internals: works
# until the representation changes, then corrupts silently.
def leak_check(bp, trie, slot):
    free = set(bp._free)                       # REPRO007
    chain = bp._chain[slot]                    # REPRO007
    budget = bp._budget[slot]                  # REPRO007
    cached = {n.block_id for n in trie._lru.values()}   # REPRO007
    trie._pinned.clear()                       # REPRO007
    return free, chain, budget, cached


def rebuild(trie, bp):
    trie._root.children = {}                   # REPRO007: write
    bp._free = []                              # REPRO007: write
