# A re-introduced pre-AttnSpec attention entry: both mode= and rescale=
# on one signature, outside core/attn_spec.py.
def attention_decode(q, k, v, length, *, scale, mode="etap", rescale=None,
                     kv_splits=None):
    return q, (scale, mode, rescale, kv_splits)
