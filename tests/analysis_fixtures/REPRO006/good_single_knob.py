# Either knob alone is fine: softmax_state helpers take rescale, CLI
# builders take mode — only both on one signature is a pre-spec entry.
def resolve_rescale(rescale=None):
    return rescale or "amla"


def build_cli_spec(mode="etap"):
    return {"mode": mode}


def spec_entry(q, k, v, length, *, spec):
    return q, spec
