# Either half of the chain ALONE is fine: oracles exponentiate shifted
# scores, rooflines do mul-adds — only both in one function is a
# hand-rolled recurrence.
import jax.numpy as jnp


def shifted_softmax_oracle(s):
    # exp-of-difference, no rescaled accumulate
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    return p / jnp.sum(p, axis=-1, keepdims=True)


def roofline_terms(bytes_hbm, flops, bw, peak):
    # mul-add store, no shifted exponential
    t = bytes_hbm * (1.0 / bw) + flops / peak
    return t
