# A hand-rolled online-softmax rescale chain: exp-of-difference
# correction weights feeding a mul-add accumulate, outside
# softmax_state.py.  Pre-§13 this was copy-pasted five times and drifted.
import jax.numpy as jnp


def my_online_softmax_step(m, l, acc, s, v):
    m_new = jnp.maximum(m, jnp.max(s, axis=0))
    corr = jnp.exp(m - m_new)                 # exp of difference
    p = jnp.exp(s - m_new)
    l = l * corr + jnp.sum(p, axis=0)         # mul-add accumulate
    acc = acc * corr + p @ v
    return m_new, l, acc
