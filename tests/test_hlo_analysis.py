"""The trip-count-aware HLO analyzer (launch/hlo_analysis.py) must agree
with hand-computable workloads — it is the source of the roofline terms."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_module


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_matmul_flops():
    def f(x, w):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)[0]
    x = jnp.zeros((128, 128))
    r = analyze(_hlo(f, x, x))
    expect = 10 * 2 * 128 ** 3
    assert 0.95 < r["flops"] / expect < 1.15


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            return jax.lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                                length=5)[0], None
        return jax.lax.scan(outer, x, None, length=4)[0]
    x = jnp.zeros((128, 128))
    r = analyze(_hlo(f, x, x))
    expect = 20 * 2 * 128 ** 3
    assert 0.95 < r["flops"] / expect < 1.15


def test_fori_loop_flops():
    def f(x, w):
        return jax.lax.fori_loop(0, 7, lambda i, c: c @ w, x)
    x = jnp.zeros((128, 128))
    r = analyze(_hlo(f, x, x))
    assert 0.95 < r["flops"] / (7 * 2 * 128 ** 3) < 1.15


def test_dynamic_slice_counts_slice_not_base():
    """Streaming a big buffer block-by-block must count ~the buffer size,
    not O(n_blocks · buffer)."""
    big = jnp.zeros((64, 4096))          # 1 MiB f32

    def f(k):
        def step(j, acc):
            blk = jax.lax.dynamic_slice_in_dim(k, j * 8, 8, axis=0)
            return acc + jnp.sum(blk * 2.0)
        return jax.lax.fori_loop(0, 8, step, 0.0)
    r = analyze(_hlo(f, big))
    base = big.size * 4
    assert r["bytes"] < 6 * base, (r["bytes"], base)   # not 8x+ the buffer


def test_parse_module_handles_tuple_types_with_index_comments():
    txt = """
HloModule m
%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4,4]{1,0}) tuple(%g0, %d)
}
%cond (p2: (s32[], f32[4,4])) -> pred[] {
  %p2 = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[4,4]{1,0}) tuple(%z, %x)
  %w = (s32[], /*index=1*/f32[4,4]{1,0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    comps = parse_module(txt)
    whiles = [i for c in comps.values() for i in c.instrs if i.op == "while"]
    assert len(whiles) >= 1
    r = analyze(txt)
    assert r["flops"] == pytest.approx(12 * 2 * 4 ** 3, rel=0.01)


def test_collective_bytes_trip_multiplied():
    txt = """
HloModule m
%body (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]{0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[256]{0} get-tuple-element(%p), index=1
  %ar = f32[256]{0} all-reduce(%g1), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[256]{0}) tuple(%g0, %ar)
}
%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
%cond (p2: (s32[], f32[256])) -> pred[] {
  %p2 = (s32[], f32[256]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (x: f32[256]) -> f32[256] {
  %x = f32[256]{0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], f32[256]{0}) tuple(%z, %x)
  %w = (s32[], f32[256]{0}) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[256]{0} get-tuple-element(%w), index=1
}
"""
    r = analyze(txt)
    assert r["collective_bytes"] == 5 * 256 * 4
    assert r["collective_by_kind"] == {"all-reduce": 5 * 256 * 4}
