"""Telemetry layer (DESIGN.md §15): histogram algebra, ring-buffer
bounds, Chrome trace-event schema, the attn_entry profiling hook, and the
serve-loop acceptance criteria — telemetry-on is BITWISE output-identical
to telemetry-off (fp and int8+prefix-cache legs) and the exported trace
covers the full request lifecycle under a contended burst."""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.runtime import telemetry


# ------------------------------------------------------------- histogram
def _exact_nearest_rank(vals, q):
    s = sorted(vals)
    return s[max(1, math.ceil(q / 100.0 * len(s))) - 1]


def test_histogram_resolution_pin():
    """Quantization contract: every percentile of a positive sample is
    within ~rel_err of the EXACT nearest-rank percentile — the
    equal-or-better-than-raw-lists resolution the scheduler's class_stats
    migration relies on (the old _pct helper interpolated over raw
    lists; the histogram must not be meaningfully coarser)."""
    rng = np.random.default_rng(0)
    vals = np.exp(rng.normal(size=2000)).tolist()          # lognormal > 0
    h = telemetry.Histogram.from_values(vals, rel_err=0.01)
    for q in (10, 50, 90, 99, 99.9):
        exact = _exact_nearest_rank(vals, q)
        assert abs(h.percentile(q) - exact) <= 0.015 * exact, q
    assert h.count == 2000
    assert abs(h.mean - np.mean(vals)) <= 0.015 * np.mean(vals)
    assert h.vmin == min(vals) and h.vmax == max(vals)


def test_histogram_zero_and_negative():
    h = telemetry.Histogram.from_values([-1.0, 0.0, 5.0])
    assert h.zero == 2 and h.count == 3
    assert h.percentile(50) == 0.0                 # rank 2 of 3 → zero bucket
    assert abs(h.percentile(100) - 5.0) <= 0.015 * 5.0
    assert telemetry.Histogram(0.01).percentile(50) == 0.0   # empty → 0


def _hist_state(h):
    return (dict(h.counts), h.zero, h.vmin, h.vmax, h.to_dict())


def _check_merge(a, b, c):
    ha = telemetry.Histogram.from_values(a)
    hb = telemetry.Histogram.from_values(b)
    hc = telemetry.Histogram.from_values(c)
    frozen = (_hist_state(ha), _hist_state(hb))
    # commutative + associative, exactly (integer bucket counts)
    assert _hist_state(ha.merge(hb)) == _hist_state(hb.merge(ha))
    assert _hist_state(ha.merge(hb).merge(hc)) \
        == _hist_state(ha.merge(hb.merge(hc)))
    # merge of split streams == single-pass over the concatenation
    assert _hist_state(ha.merge(hb)) \
        == _hist_state(telemetry.Histogram.from_values(list(a) + list(b)))
    # operands untouched
    assert (_hist_state(ha), _hist_state(hb)) == frozen


def _rand_lists(seed):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(3):
        n = int(rng.integers(0, 50))
        out.append((rng.standard_normal(n) * 10.0 ** rng.integers(-3, 4))
                   .tolist())
    return out


if HAVE_HYPOTHESIS:
    _floats = st.lists(
        st.floats(min_value=-1e9, max_value=1e9,
                  allow_nan=False, allow_infinity=False), max_size=60)

    @settings(max_examples=60, deadline=None)
    @given(_floats, _floats, _floats)
    def test_histogram_merge_property(a, b, c):
        _check_merge(a, b, c)
else:
    def test_histogram_merge_property():
        """Deterministic stand-in for the hypothesis property (keeps the
        tier-1 skip count flat when hypothesis is absent)."""
        for seed in range(60):
            _check_merge(*_rand_lists(seed))


def test_histogram_merge_resolution_mismatch():
    with pytest.raises(AssertionError):
        telemetry.Histogram(0.01).merge(telemetry.Histogram(0.05))


# -------------------------------------------------------------- registry
def test_registry_kinds_and_snapshot():
    reg = telemetry.MetricsRegistry()
    reg.counter("a/n").inc(3)
    reg.inc("a/n", 2)
    reg.gauge("a/g").set(7)
    reg.observe("a/h", 1.0)
    assert reg.counter("a/n").value == 5         # create-or-get, one object
    assert reg.value("a/n") == 5 and reg.value("a/g") == 7.0
    with pytest.raises(AssertionError):          # one name, one kind
        reg.gauge("a/n")
    snap = reg.snapshot()
    json.dumps(snap)                             # plain JSON types only
    assert snap["schema_version"] == telemetry.OBS_SCHEMA_VERSION
    assert snap["counters"] == {"a/n": 5}
    assert snap["gauges"] == {"a/g": 7.0}
    assert snap["histograms"]["a/h"]["count"] == 1
    assert reg.op_count() == 2 + 1 + 1           # incs + sets + records


# --------------------------------------------------------------- tracing
def test_tracer_ring_bounded():
    tr = telemetry.Tracer(capacity=8, clock=iter(range(10 ** 6)).__next__)
    for i in range(100):
        tr.instant(f"e{i}")
    assert tr.recorded == 100 and tr.dropped == 92
    evs = tr.to_events()
    assert len(evs) == 8 + 1                     # ring + process_name meta
    assert evs[-1]["name"] == "e99"              # newest survive


def test_trace_export_schema(tmp_path):
    tr = telemetry.Tracer(capacity=64)
    tr.instant("enqueued", tid=1001, args={"req": 1})
    with tr.span("prefill_chunk", args={"tokens": 8}):
        pass
    t0 = tr.now_us()
    tr.complete("decode_step", t0)
    path = str(tmp_path / "trace.json")
    stats = tr.export(path)
    assert stats["recorded"] == 3 and stats["dropped"] == 0
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert doc["otherData"]["schema_version"] == telemetry.OBS_SCHEMA_VERSION
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e), e
        assert e["ph"] in ("X", "i", "M")
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        if e["ph"] == "i":
            assert e["s"] == "t"
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)                      # monotonic after sort


# ------------------------------------------------------ kernel profiling
def test_profiler_sampling_pattern():
    p = telemetry.KernelProfiler(sample_every=3)
    assert [p.want() for _ in range(7)] \
        == [True, False, False, True, False, False, True]


def test_profiler_hooks_attn_entry():
    """attn_entry times concrete launches under an installed profiler and
    tags them with entry name + spec; under an outer trace (args are
    tracers, block_until_ready would be invalid) the hook must skip,
    not crash."""
    from repro.kernels.etap import ops as etap_ops
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 64)), jnp.float32)
    v = k[..., :32]
    prev = telemetry.set_profiler(telemetry.KernelProfiler(1))
    try:
        prof = telemetry.profiler()
        ref = etap_ops.etap_decode(q, k, v, None, scale=64 ** -0.5, block=64)
        assert prof.sampled == 1
        ((name, tag, geom),) = prof.records
        cnt, tot = prof.records[(name, tag, geom)]
        assert name == "etap_decode" and "mode=" in tag
        assert cnt == 1 and tot >= 0.0 and geom     # geometry captured
        jitted = jax.jit(lambda q: etap_ops.etap_decode(
            q, k, v, None, scale=64 ** -0.5, block=64))
        out = jitted(q)
        assert prof.sampled == 1                    # guard skipped the hook
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    finally:
        telemetry.set_profiler(prev)


# ------------------------------------------------------------ end to end
def _serve(argv, cfg):
    from repro.launch import serve
    return serve.run_paged(serve.parse_args(argv), cfg)


def _no_moe_cfg():
    from repro.configs import get_config, reduced
    return dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                               moe=None)


BURST = ["--reduced", "--batch", "2", "--prompt", "24", "--gen", "8",
         "--requests", "6", "--page-size", "8", "--prefill-chunk", "8",
         "--cache-layout", "paged", "--priority-classes", "3",
         "--arrival-rate", "0.25", "--trace", "burst", "--burst-size", "3",
         "--retry-backoff", "4", "--preemption", "recompute",
         "--spec-tokens", "2", "--seed", "0"]


def test_serve_trace_bitwise_and_lifecycle(tmp_path):
    """ACCEPTANCE: under a multi-tenant burst with speculation, a
    --trace-out/--metrics-out run is bitwise output-identical to a plain
    run, and the exported trace covers prefill / decode / verify spans
    plus the lifecycle instants (preemption/restore on the contended fp
    leg).  Also pins that class_stats() percentiles and the registry
    snapshot read the SAME histograms — one percentile code path."""
    cfg = _no_moe_cfg()
    plain = _serve(BURST, cfg)
    tpath, mpath = str(tmp_path / "t.json"), str(tmp_path / "m.json")
    inst = _serve(BURST + ["--trace-out", tpath, "--metrics-out", mpath],
                  cfg)
    assert inst["outputs"] == plain["outputs"]
    assert inst["tokens_served"] == plain["tokens_served"]

    doc = json.load(open(tpath))
    evs = doc["traceEvents"]
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e), e
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    names = {e["name"] for e in evs}
    need = {"enqueued", "admitted", "finished",
            "prefill_chunk", "decode_step", "verify_step"}
    if inst["kv_dtype"] == "fp":       # quantized legs widen slots and may
        assert inst["sched"]["preempts_recompute"] > 0   # never contend
        need |= {"preempted", "restored"}
    assert need <= names, names - need

    met = json.load(open(mpath))
    assert met["meta"]["schema_version"] == telemetry.OBS_SCHEMA_VERSION
    snap = inst["metrics"]
    assert met["metrics"] == snap
    assert snap["counters"]["serve/decode_tokens"] == inst["decode_tokens"]
    # class_stats() and the snapshot render from the same histograms
    for cls, cstats in inst["classes"].items():
        hd = snap["histograms"][f"sched/class{cls}/ttft_ms"]
        assert cstats["ttft_p50_ms"] == hd["p50"]
        assert cstats["ttft_p99_ms"] == hd["p99"]
        assert cstats["n"] == snap["counters"][f"sched/class{cls}/done"]


def test_serve_bitwise_int8_prefix(tmp_path):
    """ACCEPTANCE: the bitwise telemetry-on == telemetry-off identity
    holds on the int8 + prefix-cache path too."""
    cfg = _no_moe_cfg()
    base = ["--reduced", "--batch", "2", "--prompt", "16", "--gen", "8",
            "--requests", "3", "--page-size", "8", "--prefill-chunk", "8",
            "--cache-layout", "paged", "--kv-dtype", "int8",
            "--shared-prefix", "2", "--seed", "0"]
    plain = _serve(base, cfg)
    tpath, mpath = str(tmp_path / "t.json"), str(tmp_path / "m.json")
    inst = _serve(base + ["--trace-out", tpath, "--metrics-out", mpath],
                  cfg)
    assert inst["outputs"] == plain["outputs"]
    assert {"prefill_chunk", "decode_step"} \
        <= {e["name"] for e in json.load(open(tpath))["traceEvents"]}
    assert json.load(open(mpath))["metrics"]["counters"][
        "serve/decode_tokens"] == inst["decode_tokens"]


def test_fault_injection_counters_pinned():
    """Satellite: one --fault-rate drill's counter totals line up across
    subsystems — every injected fault is one scheduler failure and one
    observed worker restart, all flowing through the one registry."""
    cfg = _no_moe_cfg()
    res = _serve(["--reduced", "--batch", "2", "--prompt", "16", "--gen",
                  "8", "--requests", "3", "--page-size", "8",
                  "--prefill-chunk", "8", "--cache-layout", "paged",
                  "--fault-rate", "0.2", "--seed", "0"], cfg)
    c = res["metrics"]["counters"]
    assert c["ft/injected_faults"] > 0
    assert c["ft/injected_faults"] == c["sched/failures"]
    assert c["ft/injected_faults"] == c["serve/worker_restarts"]
    assert c["serve/worker_restarts"] == res["worker_restarts"]
    assert c["ft/heartbeats"] == c["serve/ticks"]
    assert c["serve/replayed_tokens"] == res["replayed_tokens"]
