"""Fault-tolerance drills: checkpoint atomicity, restart-equivalence,
straggler detection, elastic re-mesh planning, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.launch import train
from repro.optim import compress
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatRegistry,
                                           StragglerDetector, WorkerFailure,
                                           plan_remesh)


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": [{"c": jnp.ones((2,), jnp.bfloat16)},
                  {"c": jnp.zeros((2,), jnp.bfloat16)}],
            "n": jnp.asarray(3, jnp.int32)}
    ckpt.save(str(tmp_path), 7, tree)
    out, step = ckpt.restore(str(tmp_path), 7, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_commit_point_is_manifest(tmp_path):
    """A save that dies before the manifest is invisible to latest_step."""
    tree = {"a": jnp.ones((4,))}
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crashed save: directory exists, manifest missing
    os.makedirs(tmp_path / "step_2" / "arrays", exist_ok=True)
    np.save(tmp_path / "step_2" / "arrays" / "a.npy", np.zeros(4))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_gc(tmp_path):
    tree = {"a": jnp.ones((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.gc_old(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert not os.path.exists(tmp_path / "step_1")
    assert os.path.exists(tmp_path / "step_4")


# -------------------------------------------------- restart-equivalence drill
def test_failure_injection_and_restart_equivalence(tmp_path):
    """Train run A: uninterrupted. Run B: worker dies at step 7, restarts
    from the last checkpoint, finishes. Final losses must match exactly
    (deterministic pipeline + exact state restore)."""
    base = ["--arch", "smollm_360m", "--reduced", "--steps", "12",
            "--batch", "2", "--seq", "32", "--ckpt-every", "4",
            "--log-every", "0"]
    ref = train.run(train.parse_args(base + ["--ckpt-dir", str(tmp_path / "a")]))

    argsB = base + ["--ckpt-dir", str(tmp_path / "b")]
    with pytest.raises(WorkerFailure):
        train.run(train.parse_args(argsB + ["--fail-at", "7"]))
    out = train.run(train.parse_args(argsB + ["--restart"]))
    assert ckpt.latest_step(str(tmp_path / "b")) == 12
    np.testing.assert_allclose(ref["losses"][-1], out["losses"][-1],
                               rtol=1e-5)


# -------------------------------------------------------- detectors/planning
def test_heartbeats():
    t = [0.0]
    reg = HeartbeatRegistry(timeout_s=10, clock=lambda: t[0])
    reg.beat("h0"); reg.beat("h1")
    t[0] = 5.0; reg.beat("h0")
    t[0] = 12.0
    assert reg.alive() == ["h0"] and reg.dead() == ["h1"]


def test_straggler_detector():
    det = StragglerDetector(window=8, z=3.0)
    for i in range(8):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, 1.0 + 0.01 * i)
        det.record("slow", 3.0)
    assert det.stragglers() == ["slow"]
    det2 = StragglerDetector()
    for _ in range(8):
        for h in ("h0", "h1", "h2"):
            det2.record(h, 1.0)
    assert det2.stragglers() == []


def test_failure_injector():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(WorkerFailure):
        inj.check(3)


def test_plan_remesh():
    assert plan_remesh(64, 4, 16) == (16, 16)     # full fleet
    assert plan_remesh(60, 4, 16) == (8, 16)      # lost 4 hosts -> pow2 data
    assert plan_remesh(3, 4, 16) is None          # can't fit TP anymore


# ------------------------------------------------------- gradient compression
def test_compression_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(333,)), jnp.float32) * 10
    out = compress.compress_decompress(g)
    # int8 per-chunk: error bounded by scale/2 = max|chunk|/254
    err = np.abs(np.asarray(out - g))
    assert err.max() <= float(jnp.max(jnp.abs(g))) / 254 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the *accumulated* compressed signal tracks the
    accumulated true gradient (bias-free compression over time)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64, 7)), jnp.float32)
    residual = compress.init_residual({"w": g_true})["w"]
    acc_comp = jnp.zeros_like(g_true)
    for _ in range(50):
        out, residual = compress.pod_reduce_with_feedback(
            {"w": g_true}, {"w": residual})
        out, residual = out["w"], residual["w"]
        acc_comp = acc_comp + out
    # average transmitted ≈ true gradient
    np.testing.assert_allclose(np.asarray(acc_comp / 50), np.asarray(g_true),
                               atol=2e-3)


def test_quantize_shapes():
    q, s = compress.quantize(jnp.ones((5, 130)))
    assert q.shape[1] == compress.CHUNK and q.dtype == jnp.int8
    out = compress.dequantize(q, s, (5, 130))
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-2)
