"""Speculative decoding validation (DESIGN.md §14): draft proposers and the
greedy acceptance rule, the verify kernels/XLA twins against a per-position
masked oracle (linear chains BITWISE equal to chunked prefill — verify IS
prefill with an explicit horizon vector), model.verify_step vs
model.prefill_chunk, the end-to-end serve-loop guarantee that speculative
greedy decode delivers the exact token stream of one-at-a-time decode
(k in {1,2,4,8}, fp / int8 / prefix-cache-on), and the truncate-under-
speculation pool property (refcount conservation + COW blocks never
rewound in place)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs import get_config, reduced
from repro.core import attn_spec
from repro.core.etap import (etap_prefill_xla, etap_verify_xla,
                             prefill_attention_paged, verify_attention_paged)
from repro.kernels.etap import ops as etap_ops
from repro.models import model
from repro.runtime import paged_cache as pc
from repro.runtime import spec_decode

RNG = np.random.default_rng(31)


# ------------------------------------------------------------- proposers
def test_ngram_propose_continues_repeating_pattern():
    # suffix [2, 3] last occurred before index 5 -> continue with [1, 2, 3]
    assert spec_decode.ngram_propose([1, 2, 3, 1, 2, 3, 1, 2], 3) == [3, 1, 2]


def test_ngram_propose_prefers_most_recent_match():
    # [1, 2] occurs at 0 (-> 7) and at 3 (-> 8): recency wins
    assert spec_decode.ngram_propose([1, 2, 7, 1, 2, 8, 1, 2], 1) == [8]


def test_ngram_propose_falls_back_to_repeat_last():
    assert spec_decode.ngram_propose([4], 3) == [4, 4, 4]
    # no suffix recurs anywhere -> repeat the last token
    assert spec_decode.ngram_propose([1, 2, 3, 4, 5], 2) == [5, 5]


def test_ngram_propose_pads_short_continuation():
    # the only match's continuation runs into the suffix: pad with its last
    assert spec_decode.ngram_propose([9, 1, 9, 1], 3) == [9, 1, 1]


def test_head_draft_chains_without_self_loops():
    embed = RNG.normal(size=(16, 8)).astype(np.float32)
    hd = spec_decode.HeadDraft(embed)
    assert (hd.table != np.arange(16)).all()      # -inf diagonal: no fixpoint
    ds = hd.propose([3], 4)
    assert len(ds) == 4 and ds[0] == int(hd.table[3])
    for a, b in zip(ds, ds[1:], strict=False):
        assert b == int(hd.table[a])              # chained, not repeated


def test_make_drafter_kinds():
    assert spec_decode.make_drafter("ngram", None) is spec_decode.ngram_propose
    head = spec_decode.make_drafter(
        "head", {"embed": RNG.normal(size=(8, 4)).astype(np.float32)})
    assert len(head([2], 3)) == 3
    with pytest.raises(ValueError):
        spec_decode.make_drafter("oracle", None)


def test_accept_greedy_longest_matching_prefix():
    assert spec_decode.accept_greedy([5, 7], [5, 9, 4]) == (1, 9)
    assert spec_decode.accept_greedy([5, 9], [5, 9, 4]) == (2, 4)
    assert spec_decode.accept_greedy([6, 9], [5, 9, 4]) == (0, 5)
    assert spec_decode.accept_greedy([], [8]) == (0, 8)


def test_accept_greedy_rejects_post_miss_coincidence():
    # drafts[1] == preds[1] but drafts[0] missed: the later "match" was
    # scored against a context containing the WRONG token — reject it
    assert spec_decode.accept_greedy([6, 5], [5, 5, 4]) == (0, 5)


# ------------------------------------------------ verify kernels vs oracle
def _ref_verify(q, k, v, qpos):
    """fp64 dense oracle: query row c of batch b attends key rows <=
    qpos[b, c] — the per-position horizon the verify mask implements."""
    q64, k64, v64 = (np.asarray(a, np.float64) for a in (q, k, v))
    B, Cq, H, Dk = q64.shape
    out = np.zeros((B, Cq, H, v64.shape[-1]))
    kpos = np.arange(k64.shape[1])
    for b in range(B):
        s = np.einsum("chd,sd->chs", q64[b], k64[b]) * Dk ** -0.5
        for c in range(Cq):
            sc = s[c][:, kpos <= qpos[b, c]]
            p = np.exp(sc - sc.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[b, c] = p @ v64[b][kpos <= qpos[b, c]]
    return out


def _rmse(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.sqrt(np.mean((a - b) ** 2)))


S, CQ = 96, 5
STARTS = [5, 16, 33]


def _qkv(B, H, Dk, Dv):
    return (jnp.asarray(RNG.normal(size=(B, CQ, H, Dk)), jnp.float32),
            jnp.asarray(RNG.normal(size=(B, S, Dk)), jnp.float32),
            jnp.asarray(RNG.normal(size=(B, S, Dv)), jnp.float32))


def test_verify_xla_linear_chain_bitwise_equals_prefill():
    """On a linear chain (qpos = start + arange) the verify pass IS chunked
    prefill — bitwise, not approximately (the §14 protocol leans on this:
    accepted speculative tokens equal the non-speculative stream)."""
    q, k, v = _qkv(3, 4, 32, 24)
    start = jnp.asarray(STARTS, jnp.int32)
    qpos = start[:, None] + jnp.arange(CQ, dtype=jnp.int32)[None, :]
    scale = 32 ** -0.5
    a = etap_prefill_xla(q, k, v, start, scale=scale, block=16)
    b = etap_verify_xla(q, k, v, qpos, scale=scale, block=16)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["xla", "pallas"])
def test_verify_paged_tree_qpos_vs_oracle(use_kernels):
    """An EXPLICIT horizon vector with duplicate entries — two sibling
    draft branches sharing a parent — against the per-position oracle,
    through both the paged Pallas kernel and the XLA twin (the in-cache
    tree-verification hook)."""
    page = 16
    q, k, v = _qkv(3, 4, 32, 24)
    # rows 1 and 2 are siblings at the same horizon; row 4 jumps back
    qpos_np = np.stack([[s, s + 1, s + 1, s + 2, s] for s in STARTS])
    ref = _ref_verify(q, k, v, qpos_np)
    total = [int(r.max()) + 1 for r in qpos_np]
    k_pool, bp = pc.dense_to_paged(k, total, pc.layout_for(3, S, page))
    v_pool, _ = pc.dense_to_paged(v, total, pc.layout_for(3, S, page))
    table, _ = bp.device_views()
    start = jnp.asarray(STARTS, jnp.int32)
    out = verify_attention_paged(
        q, k_pool, v_pool, table, start, jnp.asarray(qpos_np, jnp.int32),
        spec=attn_spec.AttnSpec(scale=32 ** -0.5, use_kernels=use_kernels))
    assert _rmse(out, ref) <= 1e-4


def test_verify_paged_linear_bitwise_equals_prefill_paged():
    """verify_attention_paged on a linear chain == prefill_attention_paged
    bitwise, on the same pool, XLA and Pallas — kernel level twin of the
    serve-loop equality."""
    page = 16
    q, k, v = _qkv(3, 4, 32, 24)
    total = [s + CQ for s in STARTS]
    k_pool, bp = pc.dense_to_paged(k, total, pc.layout_for(3, S, page))
    v_pool, _ = pc.dense_to_paged(v, total, pc.layout_for(3, S, page))
    table, _ = bp.device_views()
    start = jnp.asarray(STARTS, jnp.int32)
    qpos = start[:, None] + jnp.arange(CQ, dtype=jnp.int32)[None, :]
    for uk in (False, True):
        sp = attn_spec.AttnSpec(scale=32 ** -0.5, use_kernels=uk)
        a = prefill_attention_paged(q, k_pool, v_pool, table, start, spec=sp)
        b = verify_attention_paged(q, k_pool, v_pool, table, start, qpos,
                                   spec=sp)
        assert np.array_equal(np.asarray(a), np.asarray(b)), uk


# ------------------------------------------------- model.verify_step
@pytest.fixture(scope="module")
def mla_model():
    """Reduced deepseek without MoE (the discontinuous top-k router would
    flip experts at float near-ties unrelated to the verify path)."""
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    return cfg, model.init(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def gqa_model():
    cfg = reduced(get_config("qwen3_8b"), kv_heads=2)
    return cfg, model.init(jax.random.PRNGKey(0), cfg)


def _prefilled(cfg, params, toks, *, total, page=8, kv_dtype="fp"):
    """Admit one slot per sequence and chunk-prefill `toks` into a fresh
    paged cache; returns (cache, bp)."""
    B, P = toks.shape
    layout = pc.layout_for(B, total, block_size=page)
    bp = pc.BlockPool(layout, B)
    cache = model.init_paged_cache(cfg, layout, kv_dtype=kv_dtype)
    for b in range(B):
        assert bp.admit(0, total) == b
    table, lengths = bp.device_views()
    _, cache = model.prefill_chunk(params, cfg, cache, toks, table, lengths,
                                   spec=attn_spec.AttnSpec())
    for b in range(B):
        bp.extend(b, P)
    return cache, bp


@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
def test_verify_step_bitwise_equals_prefill_chunk(mla_model, kv_dtype):
    """model.verify_step on a linear chain (qpos=None) returns logits
    BITWISE equal to running the same tokens as a prefill chunk — the
    §14 claim 'verify is prefill-shaped' at the full-model level, on fp
    and quantized pools."""
    cfg, params = mla_model
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    prompt, draft = toks[:, :8], toks[:, 8:]
    kw = dict(total=16, kv_dtype=kv_dtype)
    ca, bpa = _prefilled(cfg, params, prompt, **kw)
    cb, bpb = _prefilled(cfg, params, prompt, **kw)
    ta, la = bpa.device_views()
    lg_pf, ca = model.prefill_chunk(params, cfg, ca, draft, ta, la,
                                    spec=attn_spec.AttnSpec())
    tb, lb = bpb.device_views()
    lg_vf, cb = model.verify_step(params, cfg, cb, draft, tb, lb,
                                  spec=attn_spec.AttnSpec())
    assert np.array_equal(np.asarray(lg_pf), np.asarray(lg_vf))
    # the appended KV rows are bitwise identical too
    for xa, xb in zip(jax.tree.leaves(ca), jax.tree.leaves(cb),
                      strict=True):
        assert np.array_equal(np.asarray(xa), np.asarray(xb))


def test_verify_step_bitwise_gqa_and_kernels(mla_model, gqa_model):
    """Same contract through the GQA stack and the Pallas verify kernel."""
    for cfg, params in (gqa_model,
                        (dataclasses.replace(mla_model[0], use_kernels=True),
                         mla_model[1])):
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                                  cfg.vocab_size)
        prompt, draft = toks[:, :8], toks[:, 8:]
        ca, bpa = _prefilled(cfg, params, prompt, total=16)
        cb, bpb = _prefilled(cfg, params, prompt, total=16)
        ta, la = bpa.device_views()
        lg_pf, _ = model.prefill_chunk(params, cfg, ca, draft, ta, la,
                                       spec=attn_spec.AttnSpec())
        tb, lb = bpb.device_views()
        lg_vf, _ = model.verify_step(params, cfg, cb, draft, tb, lb,
                                     spec=attn_spec.AttnSpec())
        assert np.array_equal(np.asarray(lg_pf), np.asarray(lg_vf))


# ------------------------------------------------- serve-loop acceptance
def _no_moe_cfg():
    return dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                               moe=None)


def _serve(argv):
    from repro.launch import serve
    return serve.run_paged(serve.parse_args(argv), _no_moe_cfg())


SPEC_BASE = ["--reduced", "--batch", "2", "--prompt", "16", "--gen", "8",
             "--requests", "3", "--page-size", "8", "--prefill-chunk", "8",
             "--cache-layout", "paged", "--paranoia", "1", "--seed", "0"]


@pytest.fixture(scope="module")
def spec_baseline():
    return _serve(SPEC_BASE)


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_serve_spec_decode_bitwise_fp(spec_baseline, k):
    """ACCEPTANCE (ISSUE 8): speculative greedy decode at every window k
    delivers the EXACT token stream of one-at-a-time decode — same
    outputs, same token count — with the pool audited every tick.
    k=8 == --gen exercises the mixed path (slots fall back to the plain
    step once remaining < k)."""
    res = _serve(SPEC_BASE + ["--spec-tokens", str(k)])
    assert res["outputs"] == spec_baseline["outputs"]
    assert res["tokens_served"] == spec_baseline["tokens_served"]
    assert res["spec"]["k"] == k
    if k > 1:
        assert res["spec"]["proposed"] > 0


def test_serve_spec_decode_bitwise_int8_prefix_cache():
    """Speculation composes with the quantized pool AND the prefix cache:
    int8 KV + a shared prompt prefix, spec on vs off, bitwise."""
    base = SPEC_BASE + ["--kv-dtype", "int8", "--shared-prefix", "8"]
    r0 = _serve(base)
    r4 = _serve(base + ["--spec-tokens", "4"])
    assert r4["outputs"] == r0["outputs"]
    assert r4["tokens_served"] == r0["tokens_served"]
    assert r4["prefix"]["lookups"] > 0


# --------------------------------- truncate-under-speculation property
def test_truncate_keeps_cow_blocks_read_only():
    """'COW blocks are never rewound in place' made falsifiable: a length
    rollback INTO a shared prefix block must leave it read-only — the
    write guard fires on the next append — while the verify-shaped
    extend/truncate cycle past the shared region is fine."""
    page = 4
    bp = pc.BlockPool(pc.layout_for(2, 16, block_size=page,
                                    spare_blocks=4), 2)
    donor = bp.admit(8, 16)                  # two full blocks written
    shared = bp.block_ids(donor)[:2]
    slot, cow = bp.admit_shared(8, 16, shared)
    assert not cow                           # block-aligned: no copy needed
    start = int(bp.lengths[slot])
    bp.extend(slot, 4)                       # verify round in fresh blocks
    bp.truncate(slot, start + 1, free_blocks=False)
    bp.audit()
    assert int(bp.ref[shared[1]]) == 2       # rollback didn't drop the ref
    bp.truncate(slot, 6, free_blocks=False)  # rewind INTO the shared block
    with pytest.raises(AssertionError, match="COW violation"):
        bp.extend(slot, 1)                   # ...which stays read-only


def _drive_spec_pool(seed):
    """Random interleavings of admit / shared-admit / append / verify
    (extend k then truncate back, free_blocks=False) / preempt-rollback /
    release on chains sharing block-aligned prefixes; the full pool audit
    runs after every op and the drained pool must conserve every block."""
    rng = np.random.default_rng(seed)
    page = 4
    slots, budget = 3, 20
    layout = pc.layout_for(slots, budget, block_size=page, spare_blocks=8)
    bp = pc.BlockPool(layout, slots)
    for _ in range(80):
        op = int(rng.integers(6))
        act = [s for s in range(slots) if bp.active[s]]
        if op == 0 and len(act) < slots:
            donors = [s for s in act if bp.lengths[s] >= page]
            if donors and rng.integers(2):
                d = int(donors[int(rng.integers(len(donors)))])
                nb = int(rng.integers(1, int(bp.lengths[d]) // page + 1))
                bp.admit_shared(nb * page, budget, bp.block_ids(d)[:nb])
            else:
                bp.admit(0, budget)
        elif op == 1 and act:
            s = int(act[int(rng.integers(len(act)))])
            room = bp.budget(s) - int(bp.lengths[s])
            if room:
                bp.extend(s, int(rng.integers(1, min(room, 5) + 1)))
        elif op == 2 and act:                        # speculative verify
            s = int(act[int(rng.integers(len(act)))])
            k = int(rng.integers(1, 5))
            start = int(bp.lengths[s])
            if start + k <= bp.budget(s):
                bp.extend(s, k)                      # commit k rows...
                acc = int(rng.integers(0, k))        # ...accept 1 + acc
                bp.truncate(s, start + 1 + acc, free_blocks=False)
        elif op == 3 and act:                        # preemption rollback
            s = int(act[int(rng.integers(len(act)))])
            # never rewind INTO currently-shared blocks (borrowed OR lent)
            # and keep writing — that is the forbidden sequence
            # test_truncate_keeps_cow_blocks_read_only pins (the write
            # guard would fire on the next op into the shared block)
            lo = 0
            for i, bid in enumerate(bp.block_ids(s)):
                if int(bp.ref[bid]) > 1:
                    lo = (i + 1) * page
            keep = int(rng.integers(lo, int(bp.lengths[s]) + 1))
            bp.truncate(s, keep)                     # free_blocks=True
        elif op == 4 and act:
            bp.release(int(act[int(rng.integers(len(act)))]))
        bp.audit()
    for s in range(slots):
        if bp.active[s]:
            bp.release(s)
    bp.check_conservation()
    # every block is back on the free list: nothing leaked, nothing lost
    assert len(bp.free_ids()) == layout.num_blocks - 1


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_truncate_under_speculation_property(seed):
        _drive_spec_pool(seed)
else:
    def test_truncate_under_speculation_property():
        """Deterministic stand-in for the hypothesis property (keeps the
        tier-1 skip count flat when hypothesis is absent): seeded random
        interleavings through the same driver."""
        for seed in range(25):
            _drive_spec_pool(seed)
