"""Prefix-cache subsystem validation (DESIGN.md §10): radix-tree match /
insert-dedupe / LRU-leaf eviction, BlockPool refcount conservation under
shared admission, eager copy-on-write at mid-block divergence, the
write-into-shared-block guard, a property test driving random
admit/extend/append/release/share/evict interleavings, and the end-to-end
acceptance: a shared-prefix serve run prefills the shared blocks exactly
once (the prefill-token counter proves it) and decodes BITWISE identically
with the prefix cache on and off."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.runtime import paged_cache as pc
from repro.runtime.prefix_cache import PrefixCache

RNG = np.random.default_rng(23)


def _pool(bs=4, blocks=16, maxb=6, slots=3):
    layout = pc.PagedLayout(block_size=bs, num_blocks=blocks, max_blocks=maxb)
    return pc.BlockPool(layout, slots), PrefixCache(bs)


def _admit_prefilled(bp, trie, tokens, gen=2):
    """Admit a slot, account its whole prompt as prefilled, cache it."""
    plen = len(tokens)
    slot = bp.admit(0, plen + gen)
    assert slot is not None
    bp.extend(slot, plen)
    trie.insert(tokens, bp.block_ids(slot), bp)
    return slot


# ------------------------------------------------------------- radix tree
def test_match_walks_block_aligned_prefix():
    bp, trie = _pool()
    toks = np.arange(10)                     # blocks (0..3)(4..7) + tail 8,9
    _admit_prefilled(bp, trie, toks)
    assert len(trie) == 2                    # only FULL blocks are cached
    # a prompt sharing one block matches one block
    chain, matched = trie.match(np.asarray([0, 1, 2, 3, 9, 9]))
    assert matched == 4 and len(chain) == 1
    # a prompt sharing both blocks matches both
    chain, matched = trie.match(np.asarray([0, 1, 2, 3, 4, 5, 6, 7, 1]))
    assert matched == 8 and len(chain) == 2
    # divergence inside the first block matches nothing
    chain, matched = trie.match(np.asarray([0, 1, 2, 9, 4, 5, 6, 7]))
    assert chain == [] and matched == 0


def test_match_always_leaves_a_tail_token():
    """A fully-cached block-aligned prompt must recompute its last block:
    the final position's logits seed decode, so matched_len <= len - 1."""
    bp, trie = _pool()
    toks = np.arange(8)                      # exactly two full blocks
    _admit_prefilled(bp, trie, toks)
    chain, matched = trie.match(toks)        # same prompt again
    assert matched == 4 and len(chain) == 1  # capped: last block recomputed
    chain, matched = trie.match(np.arange(9))
    assert matched == 8                      # a 1-token tail is enough


def test_insert_dedupes_on_shared_path():
    bp, trie = _pool()
    toks = np.arange(8)
    s0 = _admit_prefilled(bp, trie, toks)
    first_chain = list(bp.block_ids(s0)[:2])
    # an identical prompt computed independently in another slot
    s1 = bp.admit(0, 10)
    bp.extend(s1, 8)
    assert trie.insert(toks, bp.block_ids(s1), bp) == 0   # all deduped
    assert len(trie) == 2
    # the duplicate stays slot-owned: releasing s1 frees ALL its blocks
    free_before = bp.num_free
    bp.release(s1)
    assert bp.num_free == free_before + 3    # blocks_for(10) all freed
    # while the first slot's cached chain survives its release
    bp.release(s0)
    chain, matched = trie.match(np.asarray(list(toks) + [99]))
    assert chain == first_chain and matched == 8
    bp.check_conservation()


def test_shared_admission_bumps_refcounts_and_skips_prefill():
    bp, trie = _pool()
    toks = np.arange(8)
    s0 = _admit_prefilled(bp, trie, toks)
    chain = list(bp.block_ids(s0)[:2])
    bp.release(s0)                           # cached set: ref 1 (trie only)
    assert all(bp.ref[b] == 1 for b in chain)
    matched_chain, matched = trie.match(np.asarray(list(toks) + [5, 6]))
    assert matched_chain == chain and matched == 8
    got = bp.admit_shared(matched, 12, matched_chain)
    assert got is not None
    slot, cow = got
    assert cow == []                         # block-aligned: nothing to copy
    assert all(bp.ref[b] == 2 for b in chain)        # slot + trie
    assert list(bp.table[slot][:2]) == chain          # prefix mapped
    assert int(bp.lengths[slot]) == 8                 # prefill resumes at 8
    bp.extend(slot, 2)                       # the unshared tail prefills
    bp.append(slot)                          # and decode writes are private
    bp.check_conservation()
    bp.release(slot)
    assert all(bp.ref[b] == 1 for b in chain)        # cached set again
    bp.check_conservation()


def test_cow_on_mid_block_divergence():
    """A cached prefix ending MID-block returns a copy-on-write pair at
    admission: the partial donor block is copied into the new slot's
    private block before any write, so the donor's rows are never
    clobbered and in-flight steps never allocate."""
    bp, trie = _pool(bs=4)
    s0 = bp.admit(0, 8)
    bp.extend(s0, 6)                         # 1 full block + 2 tokens
    donor = list(bp.block_ids(s0)[:2])
    # share 6 tokens: ceil(6/4) = 2 chain blocks, only 1 full
    got = bp.admit_shared(6, 10, donor)
    assert got is not None
    slot, cow = got
    assert cow == [(donor[1], int(bp.block_ids(slot)[1]))]
    assert int(bp.block_ids(slot)[0]) == donor[0]     # full block shared
    assert int(bp.block_ids(slot)[1]) != donor[1]     # partial block copied
    assert bp.ref[donor[0]] == 2 and bp.ref[donor[1]] == 1
    # the device-side copy the scheduler runs on the pair
    pool = jnp.asarray(RNG.normal(size=(bp.layout.num_blocks, 4, 3)),
                       jnp.float32)
    pool2 = pc.copy_block(pool, *cow[0])
    np.testing.assert_array_equal(np.asarray(pool2[cow[0][1]]),
                                  np.asarray(pool[cow[0][0]]))
    # writes resume mid-block in the PRIVATE copy — no guard trips
    bp.extend(slot, 2)
    assert int(bp.lengths[slot]) == 8
    bp.check_conservation()


def test_write_into_shared_block_is_a_cow_violation():
    """The pool refuses any write that would land in a block with
    refcount > 1 — shared and cached blocks are read-only by contract."""
    bp, _ = _pool(bs=2)
    slot = bp.admit(0, 4)
    bid = int(bp.block_ids(slot)[0])
    bp.ref_block(bid)                        # an external (trie-like) ref
    with pytest.raises(AssertionError, match="COW violation"):
        bp.extend(slot, 1)
    with pytest.raises(AssertionError, match="COW violation"):
        bp.append(slot)
    bp.unref_block(bid)
    bp.extend(slot, 1)                       # private again: write allowed


def test_eviction_lru_leaves_only_and_never_live():
    bp, trie = _pool(bs=4, blocks=32, maxb=4, slots=3)
    a, b = np.arange(8), np.asarray([0, 1, 2, 3, 9, 9, 9, 9])
    s0 = _admit_prefilled(bp, trie, a)       # root -> A -> B
    s1 = _admit_prefilled(bp, trie, b)       # root -> A -> C (A deduped)
    assert len(trie) == 3
    blk_a = int(bp.block_ids(s0)[0])
    blk_b = int(bp.block_ids(s0)[1])
    blk_c = int(bp.block_ids(s1)[1])
    # everything is slot-referenced -> nothing evictable yet
    assert trie.evict_lru(bp) is None
    bp.release(s0)
    bp.release(s1)
    # touch chain A->B so leaf C becomes the LRU leaf
    trie.match(np.asarray(list(a) + [7]))
    assert trie.evict_lru(bp) == blk_c       # LRU leaf first
    assert trie.evict_lru(bp) == blk_b       # next leaf
    assert trie.evict_lru(bp) == blk_a       # parent exposed last
    assert trie.evict_lru(bp) is None and len(trie) == 0
    assert bp.num_free == bp.layout.num_blocks - 1
    bp.check_conservation()


def test_eviction_respects_protected_chain():
    bp, trie = _pool(bs=4, blocks=32)
    s0 = _admit_prefilled(bp, trie, np.arange(8))
    bp.release(s0)
    chain, _ = trie.match(np.arange(9))
    assert trie.evict_lru(bp, protect=frozenset(chain)) is None
    assert trie.evict_lru(bp) is not None    # unprotected: evicts fine


def test_admission_under_pressure_reclaims_lru():
    """The free list reclaims from LRU trie leaves: a request that cannot
    reserve its budget evicts cached blocks instead of being refused."""
    bp, trie = _pool(bs=4, blocks=5, maxb=4, slots=2)   # 4 real blocks
    s0 = _admit_prefilled(bp, trie, np.arange(8), gen=0)  # 2 blocks cached
    bp.release(s0)
    assert bp.num_free == 2
    total = 12                               # needs 3 fresh blocks
    assert not bp.can_admit(total)
    while not bp.can_admit(total):
        assert trie.evict_lru(bp) is not None
    assert trie.evictions == 1               # one leaf was enough
    assert bp.admit(0, total) is not None
    bp.check_conservation()


def test_reclaimable_counts_only_trie_exclusive_blocks():
    """The scheduler evicts only when eviction can make the admission fit;
    `reclaimable` is that supply: cached blocks whose sole reference is
    the trie, minus any protected (just-matched) chain."""
    bp, trie = _pool(bs=4, blocks=16)
    s0 = _admit_prefilled(bp, trie, np.arange(8))
    assert trie.reclaimable(bp) == 0         # donor still maps them: ref 2
    bp.release(s0)
    assert trie.reclaimable(bp) == 2         # trie-exclusive now
    chain, _ = trie.match(np.arange(9))
    assert trie.reclaimable(bp, protect=frozenset(chain)) == 0  # protected
    got = bp.admit_shared(8, 12, chain)
    assert got is not None
    assert trie.reclaimable(bp) == 0         # mapped again: ref 2


# ------------------------------------------------- property: conservation
def _drive(seed: int) -> None:
    """Random interleaving of admit/extend/append/release/share/evict ops;
    after every op the pool must conserve blocks (free + slot-owned +
    trie-cached partition the pool) and refcounts stay non-negative."""
    layout = pc.PagedLayout(block_size=2, num_blocks=14, max_blocks=6)
    slots = 3
    bp = pc.BlockPool(layout, slots)
    trie = PrefixCache(layout.block_size)
    rng = np.random.default_rng(seed)
    prompts = [None] * slots
    pf = [0] * slots
    gen_left = [0] * slots

    def check():
        bp.check_conservation()
        free = bp.free_ids()
        owned = set()
        for s in range(slots):
            if bp.active[s]:
                owned |= set(int(x) for x in bp.block_ids(s))
        cached = trie.cached_block_ids()
        assert not free & (owned | cached)
        assert free | owned | cached == set(range(1, layout.num_blocks))

    for _ in range(120):
        op = int(rng.integers(0, 5))
        if op == 0 and bp.free_slots():                       # admit/share
            plen = int(rng.integers(1, 9))
            glen = int(rng.integers(1, 4))
            total = plen + glen
            if total > layout.max_len:
                continue
            toks = rng.integers(0, 3, size=plen)              # tiny vocab:
            chain, matched = trie.match(toks)                 # real hits
            while not bp.can_admit(total, n_shared=len(chain)):
                if trie.evict_lru(bp, protect=frozenset(chain)) is None:
                    break
            if chain:
                got = bp.admit_shared(matched, total, chain)
            else:
                s = bp.admit(0, total)
                got = None if s is None else (s, [])
            if got is not None:
                s, cow = got
                assert not cow                # trie matches: block-aligned
                prompts[s], pf[s], gen_left[s] = toks, matched, glen
        elif op == 1:                                          # extend
            cands = [s for s in range(slots) if bp.active[s]
                     and prompts[s] is not None and pf[s] < len(prompts[s])]
            if cands:
                s = cands[int(rng.integers(len(cands)))]
                c = int(rng.integers(1, len(prompts[s]) - pf[s] + 1))
                bp.extend(s, c)
                pf[s] += c
                if pf[s] == len(prompts[s]):   # prompt done: cache it
                    trie.insert(prompts[s], bp.block_ids(s), bp)
        elif op == 2:                                          # append
            cands = [s for s in range(slots) if bp.active[s]
                     and prompts[s] is not None
                     and pf[s] == len(prompts[s]) and gen_left[s] > 0]
            if cands:
                s = cands[int(rng.integers(len(cands)))]
                bp.append(s)
                gen_left[s] -= 1
        elif op == 3:                                          # release
            cands = [s for s in range(slots) if bp.active[s]]
            if cands:
                s = cands[int(rng.integers(len(cands)))]
                bp.release(s)
                prompts[s] = None
        else:                                                  # evict
            trie.evict_lru(bp)
        check()


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_refcount_conservation_property(seed):
        _drive(seed)
else:
    def test_refcount_conservation_property():
        """Deterministic stand-in for the hypothesis property (keeps the
        tier-1 skip count flat when hypothesis is absent): seeded random
        interleavings through the same driver."""
        for seed in range(25):
            _drive(seed)


# ---------------------------------------------------------- end to end
def test_serve_prefix_cache_bitwise_and_prefills_shared_once():
    """ACCEPTANCE (ISSUE 4): N requests sharing a block-aligned prefix
    prefill the shared blocks exactly once — the prefill-token counter
    proves it — and decode BITWISE identically with --prefix-cache off.
    batch=1 serializes requests so every later request can hit the cache;
    MoE is dropped because dropless routing mixes tokens across slots and
    the two runs batch different slot compositions per step."""
    from repro.configs import get_config, reduced
    from repro.launch import serve

    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    # page 8 | chunk 8 | shared 16: the matched prefix is both block- and
    # chunk-aligned, so the cached run's tail chunks land on the same chunk
    # grid as the uncached run's — bitwise, not approximately, equal
    base = ["--reduced", "--batch", "1", "--prompt", "24", "--gen", "4",
            "--requests", "3", "--page-size", "8", "--prefill-chunk", "8",
            "--shared-prefix", "16", "--cache-layout", "paged"]
    on = serve.run_paged(serve.parse_args(base), cfg)
    off = serve.run_paged(serve.parse_args(base + ["--no-prefix-cache"]),
                          cfg)
    assert on["outputs"] == off["outputs"]            # bitwise identical
    # token conservation holds under every kv layout: caching only moves
    # prompt tokens from "run" to "skipped"
    assert on["prefill_tokens"] + on["prefill_tokens_saved"] \
        == off["prefill_tokens"]
    assert off["prefill_tokens_saved"] == 0 and off["prefix"] is None
    assert on["decode_tokens"] == off["decode_tokens"] == on["tokens_served"]
    if on["batch_slots"] == 1:
        # serialized admission (the fp leg): requests 2 and 3 each skip
        # the 16 shared-prefix tokens request 1 prefilled, and exactly one
        # lookup per ADMITTED request (refusal retries don't count).
        # Quantized legs (REPRO_KV_DTYPE=int8/fp8) expand batch_slots
        # under the same byte budget, so all three requests admit COLD
        # before any donor finishes prefill — hits legitimately drop to 0
        # there (tests/test_quant.py covers quantized hits with a queue
        # deeper than the expanded slot count).
        assert on["prefill_tokens_saved"] == 2 * 16
        assert on["prefix"]["hits"] == 2 and on["prefix"]["lookups"] == 3
