"""Split-KV two-phase decode validation (DESIGN.md §3): partial+combine vs
the pure-jnp oracle across split counts and context lengths, the fully-masked
split (ℓ = 0) edge case, bit-compatibility of n_splits=1 with the single-pass
kernels, and the scheduler's monotonicity contract. All Pallas runs are
interpret=True on CPU."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.etap import etap_decode_splitkv_xla
from repro.kernels.etap import ops as etap_ops
from repro.kernels.etap.combine import combine_splits
from repro.kernels.etap.ref import etap_decode_ref
from repro.kernels.etap.schedule import plan_splits
from repro.kernels.flash_decode import ops as fd_ops

RNG = np.random.default_rng(7)


def _mk(BG, H, Dk, Dv, S, *, lengths=None):
    q = jnp.asarray(RNG.normal(size=(BG, H, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BG, S, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BG, S, Dv)), jnp.float32)
    if lengths is None:
        lengths = RNG.integers(1, S + 1, size=(BG,))
    return q, k, v, jnp.asarray(lengths, jnp.int32)


def _rmse(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.sqrt(np.mean((a - b) ** 2)))


SPLIT_SWEEP = [(n, s) for n in (1, 2, 4, 8) for s in (1024, 4096, 16384)]


@pytest.mark.parametrize("n_splits,S", SPLIT_SWEEP)
def test_splitkv_separate_v_vs_ref(n_splits, S):
    block = 512 if S >= 16384 else 256
    q, k, v, L = _mk(2, 8, 64, 64, S)
    scale = 64 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    out = etap_ops.etap_decode_splitkv(q, k, v, L, scale=scale, block=block,
                                       n_splits=n_splits)
    assert _rmse(out, ref) <= 1e-4


@pytest.mark.parametrize("n_splits,S", SPLIT_SWEEP)
def test_splitkv_mla_fused_vs_ref(n_splits, S):
    block = 512 if S >= 16384 else 256
    q, kv, _, L = _mk(2, 8, 96, 96, S)
    dv = 64                                  # V = first 64 latent columns
    scale = 96 ** -0.5
    ref = etap_decode_ref(q, kv, kv[..., :dv], L, scale=scale)
    out = etap_ops.etap_decode_mla_splitkv(q, kv, dv, L, scale=scale,
                                           block=block, n_splits=n_splits)
    assert _rmse(out, ref) <= 1e-4


@pytest.mark.parametrize("n_splits", [2, 4, 8])
def test_splitkv_fully_masked_splits(n_splits):
    """Ragged lengths that leave whole splits masked: a split beyond
    `length` carries (m = -inf-ish, ℓ = 0) and must drop out of the combine
    with weight exactly 0 — not pollute O with NaN or garbage."""
    S, block = 1024, 128
    q, k, v, L = _mk(3, 8, 64, 64, S, lengths=[1, 130, S])
    scale = 0.125
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    out = etap_ops.etap_decode_splitkv(q, k, v, L, scale=scale, block=block,
                                       n_splits=n_splits)
    assert not np.any(np.isnan(np.asarray(out)))
    assert _rmse(out, ref) <= 1e-4
    # same edge case through the XLA two-phase path
    out_x = etap_decode_splitkv_xla(q, k, v, L, scale=scale, block=block,
                                    n_splits=n_splits)
    assert _rmse(out_x, ref) <= 1e-4


def test_splitkv_one_split_bitwise_single_pass():
    """Two-phase with n_splits=1 must be BIT-compatible with the single-pass
    kernel: the combine weights degenerate to exp(0) = 1, so the merge is
    the identity and the epilogue division is the same operation."""
    q, k, v, L = _mk(2, 16, 128, 96, 1024)
    scale = 128 ** -0.5
    one = etap_ops.etap_decode(q, k, v, L, scale=scale, block=256)
    m, l, accT = etap_ops.etap_partial(q, k, v, L, scale=scale, block=256,
                                       n_splits=1)
    for combine in ("pallas", "xla"):
        two = combine_splits(m, l, accT, transposed=True, out_dtype=v.dtype,
                             combine=combine)
        np.testing.assert_array_equal(np.asarray(two), np.asarray(one))


def test_splitkv_baseline_flash_decode_vs_ref():
    """The untransposed baseline kernel's split path (standard orientation
    stats, no epilogue transpose) agrees with the same oracle."""
    q, k, v, L = _mk(2, 8, 64, 64, 2048)
    scale = 64 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    for n in (1, 2, 4):
        out = fd_ops.flash_decode_splitkv(q, k, v, L, scale=scale, block=256,
                                          n_splits=n)
        assert _rmse(out, ref) <= 1e-4
    # n=1 bitwise against the single-pass baseline kernel
    one = fd_ops.flash_decode(q, k, v, L, scale=scale, block=256)
    two = fd_ops.flash_decode_splitkv(q, k, v, L, scale=scale, block=256,
                                      n_splits=1)
    np.testing.assert_array_equal(np.asarray(two), np.asarray(one))


def test_splitkv_xla_vs_ref():
    q, k, v, L = _mk(3, 16, 576, 512, 4096)
    scale = 576 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    for n in (1, 2, 4, 8):
        out = etap_decode_splitkv_xla(q, k, v, L, scale=scale, block=512,
                                      n_splits=n)
        assert _rmse(out, ref) <= 1e-4


def test_splitkv_ragged_tail_padding():
    """S not divisible by n_splits*block: the padded tail must be masked."""
    q, k, v, L = _mk(2, 8, 64, 64, 1000, lengths=[999, 1000])
    scale = 0.1
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    out = etap_ops.etap_decode_splitkv(q, k, v, L, scale=scale, block=128,
                                       n_splits=4)
    assert _rmse(out, ref) <= 1e-4


# ---------------------------------------------------------------- scheduler
def test_scheduler_monotone_in_context_length():
    """FlashMLA num_splits contract: split count grows monotonically with S
    (more context → more parallel work), at fixed batch/head geometry."""
    seqs = [256, 512, 1024, 4096, 16384, 65536, 262144]
    ns = [plan_splits(1, s, 16, 512).n_splits for s in seqs]
    assert all(a <= b for a, b in zip(ns, ns[1:])), ns
    assert ns[-1] > 1                      # long context does split
    assert plan_splits(1, 256, 16, 512).n_splits == 1   # short doesn't


def test_scheduler_large_batch_stays_single_pass():
    """At the paper's batch-16 geometry the grid is already occupancy-bound;
    the scheduler must not pay combine overhead for nothing."""
    assert plan_splits(64, 65536, 16, 512).n_splits == 1


def test_scheduler_split_granularity():
    """Every split owns at least one full KV block and the padded context
    the plan implies covers S."""
    for s in (512, 4096, 65536):
        for bg in (1, 4, 16):
            p = plan_splits(bg, s, 16, 512)
            assert p.n_splits >= 1 and p.nb_per_split >= 1
            assert p.padded_s >= s
