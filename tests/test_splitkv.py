"""Split-KV two-phase decode validation (DESIGN.md §3): partial+combine vs
the pure-jnp oracle across split counts and context lengths, the fully-masked
split (ℓ = 0) edge case, bit-compatibility of n_splits=1 with the single-pass
kernels, and the scheduler's monotonicity contract. All Pallas runs are
interpret=True on CPU."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.etap import etap_decode_splitkv_xla
from repro.kernels.etap import ops as etap_ops
from repro.kernels.etap.combine import combine_splits
from repro.kernels.etap.ref import etap_decode_ref
from repro.kernels.etap.schedule import (paged_split_geometry, plan_splits,
                                         split_geometry)
from repro.kernels.flash_decode import ops as fd_ops

RNG = np.random.default_rng(7)


def _mk(BG, H, Dk, Dv, S, *, lengths=None):
    q = jnp.asarray(RNG.normal(size=(BG, H, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(BG, S, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(BG, S, Dv)), jnp.float32)
    if lengths is None:
        lengths = RNG.integers(1, S + 1, size=(BG,))
    return q, k, v, jnp.asarray(lengths, jnp.int32)


def _rmse(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.sqrt(np.mean((a - b) ** 2)))


SPLIT_SWEEP = [(n, s) for n in (1, 2, 4, 8) for s in (1024, 4096, 16384)]


@pytest.mark.parametrize("n_splits,S", SPLIT_SWEEP)
def test_splitkv_separate_v_vs_ref(n_splits, S):
    block = 512 if S >= 16384 else 256
    q, k, v, L = _mk(2, 8, 64, 64, S)
    scale = 64 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    out = etap_ops.etap_decode_splitkv(q, k, v, L, scale=scale, block=block,
                                       n_splits=n_splits)
    assert _rmse(out, ref) <= 1e-4


@pytest.mark.parametrize("n_splits,S", SPLIT_SWEEP)
def test_splitkv_mla_fused_vs_ref(n_splits, S):
    block = 512 if S >= 16384 else 256
    q, kv, _, L = _mk(2, 8, 96, 96, S)
    dv = 64                                  # V = first 64 latent columns
    scale = 96 ** -0.5
    ref = etap_decode_ref(q, kv, kv[..., :dv], L, scale=scale)
    out = etap_ops.etap_decode_mla_splitkv(q, kv, dv, L, scale=scale,
                                           block=block, n_splits=n_splits)
    assert _rmse(out, ref) <= 1e-4


@pytest.mark.parametrize("n_splits", [2, 4, 8])
def test_splitkv_fully_masked_splits(n_splits):
    """Ragged lengths that leave whole splits masked: a split beyond
    `length` carries (m = -inf-ish, ℓ = 0) and must drop out of the combine
    with weight exactly 0 — not pollute O with NaN or garbage."""
    S, block = 1024, 128
    q, k, v, L = _mk(3, 8, 64, 64, S, lengths=[1, 130, S])
    scale = 0.125
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    out = etap_ops.etap_decode_splitkv(q, k, v, L, scale=scale, block=block,
                                       n_splits=n_splits)
    assert not np.any(np.isnan(np.asarray(out)))
    assert _rmse(out, ref) <= 1e-4
    # same edge case through the XLA two-phase path
    out_x = etap_decode_splitkv_xla(q, k, v, L, scale=scale, block=block,
                                    n_splits=n_splits)
    assert _rmse(out_x, ref) <= 1e-4


def test_splitkv_one_split_bitwise_single_pass():
    """Two-phase with n_splits=1 must be BIT-compatible with the single-pass
    kernel: the combine weights degenerate to exp(0) = 1, so the merge is
    the identity and the epilogue division is the same operation."""
    q, k, v, L = _mk(2, 16, 128, 96, 1024)
    scale = 128 ** -0.5
    one = etap_ops.etap_decode(q, k, v, L, scale=scale, block=256)
    m, l, accT = etap_ops.etap_partial(q, k, v, L, scale=scale, block=256,
                                       n_splits=1)
    for combine in ("pallas", "xla"):
        two = combine_splits(m, l, accT, transposed=True, out_dtype=v.dtype,
                             combine=combine)
        np.testing.assert_array_equal(np.asarray(two), np.asarray(one))


def test_splitkv_baseline_flash_decode_vs_ref():
    """The untransposed baseline kernel's split path (standard orientation
    stats, no epilogue transpose) agrees with the same oracle."""
    q, k, v, L = _mk(2, 8, 64, 64, 2048)
    scale = 64 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    for n in (1, 2, 4):
        out = fd_ops.flash_decode_splitkv(q, k, v, L, scale=scale, block=256,
                                          n_splits=n)
        assert _rmse(out, ref) <= 1e-4
    # n=1 bitwise against the single-pass baseline kernel
    one = fd_ops.flash_decode(q, k, v, L, scale=scale, block=256)
    two = fd_ops.flash_decode_splitkv(q, k, v, L, scale=scale, block=256,
                                      n_splits=1)
    np.testing.assert_array_equal(np.asarray(two), np.asarray(one))


def test_splitkv_xla_vs_ref():
    q, k, v, L = _mk(3, 16, 576, 512, 4096)
    scale = 576 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    for n in (1, 2, 4, 8):
        out = etap_decode_splitkv_xla(q, k, v, L, scale=scale, block=512,
                                      n_splits=n)
        assert _rmse(out, ref) <= 1e-4


def test_splitkv_ragged_tail_padding():
    """S not divisible by n_splits*block: the padded tail must be masked."""
    q, k, v, L = _mk(2, 8, 64, 64, 1000, lengths=[999, 1000])
    scale = 0.1
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    out = etap_ops.etap_decode_splitkv(q, k, v, L, scale=scale, block=128,
                                       n_splits=4)
    assert _rmse(out, ref) <= 1e-4


# ---------------------------------------------------------------- scheduler
def test_scheduler_monotone_in_context_length():
    """FlashMLA num_splits contract: split count grows monotonically with S
    (more context → more parallel work), at fixed batch/head geometry."""
    seqs = [256, 512, 1024, 4096, 16384, 65536, 262144]
    ns = [plan_splits(1, s, 16, 512).n_splits for s in seqs]
    assert all(a <= b for a, b in zip(ns, ns[1:], strict=False)), ns
    assert ns[-1] > 1                      # long context does split
    assert plan_splits(1, 256, 16, 512).n_splits == 1   # short doesn't


def test_scheduler_large_batch_stays_single_pass():
    """At the paper's batch-16 geometry the grid is already occupancy-bound;
    the scheduler must not pay combine overhead for nothing."""
    assert plan_splits(64, 65536, 16, 512).n_splits == 1


def test_scheduler_split_granularity():
    """Every split owns at least one full KV block and the padded context
    the plan implies covers S."""
    for s in (512, 4096, 65536):
        for bg in (1, 4, 16):
            p = plan_splits(bg, s, 16, 512)
            assert p.n_splits >= 1 and p.nb_per_split >= 1
            assert p.padded_s >= s


def test_split_geometry_exhaustive_small_shapes():
    """ISSUE 5 satellite: exhaustive small-shape sweep of the canonical
    geometry.  Invariants for EVERY (S, block, n_splits) request:
      · the effective count never exceeds the real block count (so no
        split is pure zero-length padding),
      · every split's first block index lands inside the real context,
      · padding covers S and honours the kernels' divisibility contract,
      · degrading is monotone: asking for more splits never yields fewer.
    The old geometry emitted (n-1)*npb >= nb splits of pure padding for
    n_splits > nb — each a grid row computing a fully-masked block."""
    for S in range(1, 10):
        for n_req in range(1, 10):
            for block in range(1, 6):
                blk, n, npb, padded = split_geometry(S, block, n_req)
                nb = -(-S // blk)
                assert 1 <= n <= min(n_req, nb), (S, block, n_req, n)
                assert (n - 1) * npb < nb            # no all-padding split
                assert padded == n * npb * blk >= S
    # monotone degrade at fixed (S, block)
    for S in (1, 3, 5, 9):
        for block in (1, 2, 4):
            ns = [split_geometry(S, block, r)[1] for r in range(1, 12)]
            assert all(a <= b for a, b in zip(ns, ns[1:], strict=False)), (S, block, ns)
    # paged twin: same invariants at table granularity
    for nb in range(1, 10):
        for n_req in range(1, 12):
            n, npb, padded = paged_split_geometry(nb, n_req)
            assert 1 <= n <= min(n_req, nb)
            assert (n - 1) * npb < nb
            assert padded == n * npb >= nb


@pytest.mark.parametrize("S,block,n_req", [
    (4, 512, 8),     # S < block AND n_splits > nb: collapses to 1 split
    (96, 32, 8),     # nb=3 < 8 requested
    (5, 2, 4),       # nb=3, npb=1 -> 3 effective
    (1, 1, 7),       # single token
])
def test_splitkv_degrades_not_zero_length(S, block, n_req):
    """Entry points with n_splits > nb must compute the right answer via
    fewer non-empty splits (the old path launched zero-length splits that
    only the combine's ℓ=0 weight kept from corrupting O)."""
    q, k, v, L = _mk(2, 4, 16, 16, S)
    scale = 16 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    out = etap_ops.etap_decode_splitkv(q, k, v, L, scale=scale, block=block,
                                       n_splits=n_req)
    assert _rmse(out, ref) <= 1e-5
    out_x = etap_decode_splitkv_xla(q, k, v, L, scale=scale, block=block,
                                    n_splits=n_req)
    assert _rmse(out_x, ref) <= 1e-5
    out_f = fd_ops.flash_decode_splitkv(q, k, v, L, scale=scale,
                                        block=block, n_splits=n_req)
    assert _rmse(out_f, ref) <= 1e-5
    # the phase-1 wrapper reports the effective split count in its shapes
    m, l, acc = etap_ops.etap_partial(q, k, v, L, scale=scale, block=block,
                                      n_splits=n_req)
    blk, n_eff, npb, _ = split_geometry(S, block, n_req)
    assert m.shape[1] == n_eff <= -(-S // blk)


def test_paged_splitkv_degrades_not_zero_length():
    """Paged twin: a 3-column table asked for 8 splits runs 3."""
    from repro.runtime import paged_cache as pc
    S, page = 40, 16                          # 3 table columns
    q, k, v, L = _mk(2, 4, 16, 16, S, lengths=[23, 40])
    scale = 16 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    layout = pc.layout_for(2, S, block_size=page)
    k_pool, bp = pc.dense_to_paged(k, np.asarray(L), layout)
    v_pool, _ = pc.dense_to_paged(v, np.asarray(L), layout)
    table, lengths = bp.device_views()
    out = etap_ops.etap_decode_paged_splitkv(q, k_pool, v_pool, table,
                                             lengths, scale=scale,
                                             n_splits=8)
    assert _rmse(out, ref) <= 1e-5


# ----------------------------------------------------------------- combine
def test_combine_fp32_invariant_bf16_output():
    """ISSUE 5 satellite: the phase-2 merge must stay fp32 END-TO-END and
    only cast O at the epilogue.  Oracle: fp64 stats merged in fp64.  The
    check that would catch a premature downcast: hand the combine bf16
    stats — the upcast-on-entry contract bounds the result by bf16 INPUT
    rounding (~1e-2 relative), while a merge computed IN bf16 (exp/sum in
    half precision, the pre-fix dtype-following behaviour) drifts far
    beyond it on near-tie split maxima."""
    BG, n, H, Dv = 3, 4, 8, 16
    # near-tie maxima across splits: the regime where half-precision
    # exp(m - m*) collapses distinct weights
    m = jnp.asarray(10.0 + 1e-2 * RNG.random(size=(BG, n, H)), jnp.float32)
    l = jnp.asarray(1.0 + RNG.random(size=(BG, n, H)), jnp.float32)
    acc = jnp.asarray(RNG.normal(size=(BG, n, Dv, H)), jnp.float32)

    def oracle(m, l, acc):
        m64, l64, a64 = (np.asarray(x, np.float64) for x in (m, l, acc))
        mg = m64.max(1, keepdims=True)
        w = np.exp(m64 - mg)
        lg = (l64 * w).sum(1)
        ag = (a64 * w[:, :, None, :]).sum(1)
        return np.swapaxes(ag / lg[:, None, :], 1, 2)

    ref = oracle(m, l, acc)
    for backend in ("pallas", "xla"):
        # fp32 stats, bf16 output: only the epilogue cast may lose bits
        o32 = combine_splits(m, l, acc, transposed=True,
                             out_dtype=jnp.bfloat16, combine=backend)
        assert o32.dtype == jnp.bfloat16
        err32 = np.abs(np.asarray(o32, np.float64) - ref).max()
        assert err32 <= np.abs(ref).max() * 1e-2 + 1e-3, (backend, err32)
        # bf16 stats: the upcast-on-entry contract keeps the error at the
        # level of the INPUT rounding, not of half-precision arithmetic
        mb, lb, ab = (x.astype(jnp.bfloat16) for x in (m, l, acc))
        ob = combine_splits(mb, lb, ab, transposed=True,
                            out_dtype=jnp.bfloat16, combine=backend)
        refb = oracle(mb.astype(jnp.float32), lb.astype(jnp.float32),
                      ab.astype(jnp.float32))
        errb = np.abs(np.asarray(ob, np.float64) - refb).max()
        assert errb <= np.abs(refb).max() * 2e-2 + 1e-3, (backend, errb)


def test_combine_untransposed_fp32_invariant():
    """Same contract for the baseline (untransposed) orientation."""
    BG, n, H, Dv = 2, 3, 4, 8
    m = jnp.asarray(5.0 + 1e-2 * RNG.random(size=(BG, n, H)), jnp.float32)
    l = jnp.asarray(1.0 + RNG.random(size=(BG, n, H)), jnp.float32)
    acc = jnp.asarray(RNG.normal(size=(BG, n, H, Dv)), jnp.float32)
    o_ref = combine_splits(m, l, acc, transposed=False,
                           out_dtype=jnp.float32, combine="xla")
    for backend in ("pallas", "xla"):
        ob = combine_splits(m.astype(jnp.bfloat16), l.astype(jnp.bfloat16),
                            acc.astype(jnp.bfloat16), transposed=False,
                            out_dtype=jnp.bfloat16, combine=backend)
        err = np.abs(np.asarray(ob, np.float64)
                     - np.asarray(o_ref, np.float64)).max()
        assert err <= np.abs(np.asarray(o_ref)).max() * 2e-2 + 1e-3
