"""Quantized paged KV cache validation (DESIGN.md §11, ISSUE 5).

Four layers of proof, mirroring the fp paged suite (tests/test_paged.py):
  · quantize→dequantize round-trips at adversarial values (all-zero rows,
    single-outlier rows, negative-max rows) and BITWISE-stable
    re-quantization — the property prefix-cache bitwise equality rides on;
  · in-kernel dequant correctness: every quantized Pallas path (paged
    single-pass, split-KV partials+combine, chunked prefill; MLA-fused and
    separate-V) against the dense-dequant oracle (kernels/etap/ref.py) —
    these must agree to float noise, the quantization error itself is
    ALREADY in the oracle;
  · accuracy budget vs the fp32 reference: int8 RMSE <= 5e-3, fp8 <= 2e-2
    on the smoke shapes (the acceptance gates bench_quant also enforces);
  · COW/scale co-movement and serve-loop capacity: copy_block moves codes
    AND (scale, zp) together, int8 admits >= 1.8x the sequences of fp
    under the same pool byte budget, and prefix-cache on/off stays
    bitwise identical WITHIN the quantized layout.
All Pallas runs are interpret=True on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.etap import decode_attention_paged, prefill_attention_paged
from repro.kernels.etap import ops as etap_ops
from repro.kernels.etap.ref import (dequantize, etap_decode_quant_ref,
                                    etap_decode_ref)
from repro.runtime import paged_cache as pc

RNG = np.random.default_rng(7)
QUANT_LAYOUTS = ["int8"] + (["fp8"] if pc.HAS_FP8 else [])
RMSE_BUDGET = {"int8": 5e-3, "fp8": 2e-2}


def _rmse(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.sqrt(np.mean((a - b) ** 2)))


# ------------------------------------------------------------- round trips
@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
def test_roundtrip_adversarial_rows(kv_dtype):
    """Per-row scale correctness at the values that break naive scaling:
    all-zero rows (scale guard), single-outlier rows (range capture),
    all-negative rows (max < 0), and constant rows (range 0, value != 0)."""
    F = 64
    rows = np.zeros((6, F), np.float32)
    rows[1, 3] = 1000.0                       # single positive outlier
    rows[2] = -RNG.uniform(1.0, 2.0, F)       # negative-max row
    rows[3] = 5.0                             # constant non-zero (range 0)
    rows[4] = RNG.normal(size=F)
    rows[5, 7] = -1e-3                        # tiny range
    codes, sz = pc.quantize_rows(jnp.asarray(rows), kv_dtype)
    deq = np.asarray(pc.dequantize_rows(codes, sz))
    # all-zero and constant rows are EXACT (scale guard keeps the affine
    # invertible: codes 0, zp = the constant)
    np.testing.assert_array_equal(deq[0], rows[0])
    if kv_dtype == "int8":
        np.testing.assert_array_equal(deq[3], rows[3])
    # every row's error stays within one quantization step of ITS range:
    # int8 resolves the row range in 254 steps; e4m3's 3 mantissa bits
    # give half-ULP relative error <= 1/16 of the value's binade, so the
    # worst absolute error across a row is amax/16
    rng_row = rows.max(1) - rows.min(1)
    step = {"int8": rng_row / 254.0,
            "fp8": np.abs(rows).max(1) / 16.0}[kv_dtype]
    err = np.abs(deq - rows).max(1)
    assert (err <= np.maximum(step, 1e-7) + 1e-7).all(), (err, step)
    # the outlier itself must be representable (scale follows the max)
    assert abs(deq[1, 3] - 1000.0) <= max(np.asarray(step)[1], 16.0)


@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
def test_requantization_bitwise_stable(kv_dtype):
    """Quantization is a pure function of the row values: the same rows
    quantize to identical codes AND identical (scale, zp) every time —
    the property that makes prefix-cached decode bitwise equal to
    uncached within a quantized layout."""
    rows = jnp.asarray(RNG.normal(size=(16, 48)), jnp.float32)
    c1, s1 = pc.quantize_rows(rows, kv_dtype)
    c2, s2 = pc.quantize_rows(rows, kv_dtype)
    np.testing.assert_array_equal(np.asarray(c1).view(np.uint8),
                                  np.asarray(c2).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # and round-tripping the DEQUANTIZED values re-quantizes bitwise too
    # (idempotence: the dequant grid is a fixed point of the quantizer)
    c3, s3 = pc.quantize_rows(pc.dequantize_rows(c1, s1), kv_dtype)
    np.testing.assert_allclose(np.asarray(pc.dequantize_rows(c3, s3)),
                               np.asarray(pc.dequantize_rows(c1, s1)),
                               atol=1e-6)


@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
def test_cow_copy_moves_codes_and_scales(kv_dtype):
    """copy_block on a quantized pool must move the code block AND its
    (scale, zp) block as one unit — a COW copy that dropped the scales
    would dequantize the copied prefix with the TARGET's stale affine.
    The copied block is bitwise identical to its donor."""
    N, bs, F = 5, 8, 32
    pool_fp = jnp.asarray(RNG.normal(size=(N, bs, F)), jnp.float32)
    codes, sz = pc.quantize_pool(pool_fp, kv_dtype)
    codes2 = pc.copy_block(codes, 2, 4)
    sz2 = pc.copy_block(sz, 2, 4)
    np.testing.assert_array_equal(np.asarray(codes2[4]).view(np.uint8),
                                  np.asarray(codes[2]).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(sz2[4]), np.asarray(sz[2]))
    # dequantized content follows bitwise
    np.testing.assert_array_equal(
        np.asarray(pc.dequantize_rows(codes2, sz2)[4]),
        np.asarray(pc.dequantize_rows(codes, sz)[2]))


@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
def test_model_copy_paged_block_covers_sz_pools(kv_dtype):
    """model.copy_paged_block tree-maps the whole cache pytree, so the
    "*_sz" leaves of a quantized cache ride along with the code pools —
    the prefix-cache COW path needs no quantization-aware special case."""
    from repro.configs import get_config, reduced
    from repro.models import model
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    layout = pc.PagedLayout(block_size=4, num_blocks=6, max_blocks=4)
    cache = model.init_paged_cache(cfg, layout, kv_dtype=kv_dtype)
    # scribble distinguishable values into block 1 of every leaf
    cache = jax.tree.map(
        lambda p: p.at[:, 1].set(jnp.ones_like(p[:, 1])), cache)
    copied = model.copy_paged_block(cache, 1, 3)
    for src_leaf, dst_leaf in zip(jax.tree.leaves(cache),
                                  jax.tree.leaves(copied), strict=True):
        np.testing.assert_array_equal(
            np.asarray(dst_leaf[:, 3].astype(jnp.float32)),
            np.asarray(src_leaf[:, 1].astype(jnp.float32)))


# --------------------------------------------------- kernels vs the oracle
S = 320
RAGGED = [7, 64, 65, 320]


def _quant_paged(dense, lengths, page, kv_dtype):
    layout = pc.layout_for(dense.shape[0], dense.shape[1], block_size=page,
                           spare_blocks=2)
    pool, bp = pc.dense_to_paged(dense, np.asarray(lengths), layout)
    codes, sz = pc.quantize_pool(pool, kv_dtype)
    return codes, sz, bp


@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
@pytest.mark.parametrize("n_splits", [1, 4])
def test_quant_paged_mla_fused_kernel_vs_oracle(kv_dtype, n_splits):
    """Quantized paged MLA decode (single-pass and split-KV) against the
    dense-dequant oracle: the kernels' in-register dequant must match the
    reference dequant to float noise — and both must sit inside the
    layout's RMSE budget of the fp32 reference."""
    q = jnp.asarray(RNG.normal(size=(4, 8, 96)), jnp.float32)
    kv = jnp.asarray(RNG.normal(size=(4, S, 96)), jnp.float32)
    dv, scale = 64, 96 ** -0.5
    L = jnp.asarray(RAGGED, jnp.int32)
    codes, sz, bp = _quant_paged(kv, RAGGED, 16, kv_dtype)
    table, lengths = bp.device_views()
    out = etap_ops.etap_decode_mla_paged_splitkv(
        q, codes, dv, table, lengths, scale=scale, n_splits=n_splits,
        kv_sz=sz)
    kd = pc.gather_blocks(codes, table)
    szd = pc.gather_blocks(sz, table)
    oracle = etap_decode_quant_ref(q, kd, szd, None, None, L, scale=scale,
                                   dv=dv)
    assert _rmse(out, oracle) <= 1e-5
    ref = etap_decode_ref(q, kv, kv[..., :dv], L, scale=scale)
    assert _rmse(out, ref) <= RMSE_BUDGET[kv_dtype]
    # the XLA twin (gather + dense dequant + blockwise loop) agrees too
    out_x = decode_attention_paged(q, codes, None, table, lengths,
                                   scale=scale, dv=dv, k_sz=sz, n_splits=1)
    assert _rmse(out_x, oracle) <= 1e-5


@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
@pytest.mark.parametrize("n_splits", [1, 4])
def test_quant_paged_separate_v_kernel_vs_oracle(kv_dtype, n_splits):
    q = jnp.asarray(RNG.normal(size=(4, 8, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(4, S, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(4, S, 48)), jnp.float32)
    scale = 64 ** -0.5
    L = jnp.asarray(RAGGED, jnp.int32)
    k_codes, k_sz, bp = _quant_paged(k, RAGGED, 16, kv_dtype)
    v_codes, v_sz, _ = _quant_paged(v, RAGGED, 16, kv_dtype)
    table, lengths = bp.device_views()
    out = etap_ops.etap_decode_paged_splitkv(
        q, k_codes, v_codes, table, lengths, scale=scale,
        n_splits=n_splits, k_sz=k_sz, v_sz=v_sz)
    oracle = etap_decode_quant_ref(
        q, pc.gather_blocks(k_codes, table), pc.gather_blocks(k_sz, table),
        pc.gather_blocks(v_codes, table), pc.gather_blocks(v_sz, table),
        L, scale=scale)
    assert _rmse(out, oracle) <= 1e-5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    assert _rmse(out, ref) <= RMSE_BUDGET[kv_dtype]


@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
def test_quant_chunked_prefill_kernel_vs_xla(kv_dtype):
    """Quantized chunked prefill: the Pallas kernel and the XLA gather
    twin see the SAME quantized pool, so they must agree to float noise;
    both must track the fp chunked prefill within the RMSE budget."""
    B, CQ, H, DIM, DV, page = 2, 8, 4, 96, 64, 16
    lengths = [24, 40]                      # chunk starts (pool rows before)
    total = [l + CQ for l in lengths]
    kv = jnp.asarray(RNG.normal(size=(B, 64, DIM)), jnp.float32)
    q = jnp.asarray(RNG.normal(size=(B, CQ, H, DIM)), jnp.float32)
    scale = DIM ** -0.5
    codes, sz, bp = _quant_paged(kv, total, page, kv_dtype)
    table, _ = bp.device_views()
    starts = jnp.asarray(lengths, jnp.int32)
    out_k = etap_ops.etap_prefill_mla_paged(q, codes, DV, table, starts,
                                            scale=scale, kv_sz=sz)
    out_x = prefill_attention_paged(q, codes, None, table, starts,
                                    scale=scale, dv=DV, k_sz=sz)
    assert _rmse(out_k, out_x) <= 1e-5
    # fp path on the same logical rows, only the storage layout differs
    pool_fp, bp_fp = pc.dense_to_paged(kv, np.asarray(total),
                                       pc.layout_for(B, 64, block_size=page,
                                                     spare_blocks=2))
    table_fp, _ = bp_fp.device_views()
    out_fp = etap_ops.etap_prefill_mla_paged(q, pool_fp, DV, table_fp,
                                             starts, scale=scale)
    assert _rmse(out_k, out_fp) <= RMSE_BUDGET[kv_dtype]


@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
def test_quant_append_rows_then_decode_matches_wholesale(kv_dtype):
    """Quantize-on-write (append_rows_quant / append_chunk_quant) lands
    the same codes as quantizing the packed pool wholesale: writes are
    row-granular and quantization is a pure per-row function, so HOW rows
    entered the pool cannot change their stored form."""
    B, Sx, F, page = 2, 32, 24, 8
    dense = jnp.asarray(RNG.normal(size=(B, Sx, F)), jnp.float32)
    layout = pc.layout_for(B, Sx, block_size=page)
    # path A: pack fp then quantize wholesale
    pool_fp, bp = pc.dense_to_paged(dense, [Sx, Sx], layout)
    codes_a, sz_a = pc.quantize_pool(pool_fp, kv_dtype)
    # path B: start empty, append a chunk then token-by-token rows
    qdt = pc.quant_dtype(kv_dtype)
    codes_b = jnp.zeros((layout.num_blocks, page, F), qdt)
    sz_b = jnp.concatenate(
        [jnp.ones((layout.num_blocks, page, 1), jnp.float32),
         jnp.zeros((layout.num_blocks, page, 1), jnp.float32)], -1)
    table = jnp.asarray(bp.table)
    lens = jnp.zeros((B,), jnp.int32)
    C = 20
    codes_b, sz_b = pc.append_chunk_quant(codes_b, sz_b, table, lens,
                                          dense[:, :C])
    for t in range(C, Sx):
        codes_b, sz_b = pc.append_rows_quant(
            codes_b, sz_b, table, jnp.full((B,), t, jnp.int32), dense[:, t])
    live = np.asarray(bp.table).reshape(-1)
    live = live[live != pc.NULL_BLOCK]
    np.testing.assert_array_equal(
        np.asarray(codes_a[live]).view(np.uint8),
        np.asarray(codes_b[live]).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(sz_a[live]),
                                  np.asarray(sz_b[live]))


def test_dequantize_twin_is_the_runtime_affine():
    rows = jnp.asarray(RNG.normal(size=(4, 8)), jnp.float32)
    codes, sz = pc.quantize_rows(rows, "int8")
    np.testing.assert_array_equal(np.asarray(dequantize(codes, sz)),
                                  np.asarray(pc.dequantize_rows(codes, sz)))


# -------------------------------------------------- capacity + serve loop
def test_layout_for_bytes_fp_reproduces_layout_for():
    """At the fp row size the byte-budget sizing is EXACTLY the slot-count
    sizing — one code path serves both, so they can never drift."""
    for B, max_len, bs in ((2, 96, 16), (4, 64, 8), (1, 128, 64)):
        base = pc.layout_for(B, max_len, block_size=bs)
        row = 100
        budget = (base.num_blocks - 1) * bs * row
        layout, slots = pc.layout_for_bytes(budget, row, max_len,
                                            block_size=bs)
        assert slots == B
        assert layout.num_blocks == base.num_blocks
        assert layout.max_blocks == base.max_blocks


def test_int8_capacity_ratio_ge_1_8x():
    """ACCEPTANCE (ISSUE 5): under the SAME pool byte budget the int8
    layout must admit >= 1.8x the concurrent full-length sequences of the
    fp layout (bf16 config: 2-byte rows vs 1-byte codes + 8/row sz)."""
    from repro.configs import get_config, reduced
    from repro.models import model
    cfg = reduced(get_config("deepseek_r1_671b"))
    fp_row = model.paged_row_bytes(cfg, "fp")
    q_row = model.paged_row_bytes(cfg, "int8")
    B, max_len, bs = 4, 96, 16
    budget = (pc.layout_for(B, max_len, block_size=bs).num_blocks - 1) \
        * bs * fp_row
    _, fp_slots = pc.layout_for_bytes(budget, fp_row, max_len,
                                      block_size=bs)
    _, q_slots = pc.layout_for_bytes(budget, q_row, max_len, block_size=bs)
    assert fp_slots == B
    assert q_slots >= 1.8 * fp_slots, (q_slots, fp_slots)


def test_serve_int8_admits_more_and_prefix_stays_bitwise():
    """End to end through the serve loop: --kv-dtype int8 expands the
    admitted batch >= 1.8x over fp under the same byte budget, the prefix
    cache still HITS once the queue outruns the expanded slots, and
    prefix-cache on/off outputs stay BITWISE identical within the int8
    layout (quantize-on-write is a pure row function, so donor-written
    blocks decode exactly as self-written ones)."""
    from repro.configs import get_config, reduced
    from repro.launch import serve
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    base = ["--reduced", "--batch", "1", "--prompt", "24", "--gen", "4",
            "--requests", "8", "--page-size", "8", "--prefill-chunk", "8",
            "--shared-prefix", "16", "--cache-layout", "paged"]
    fp = serve.run_paged(serve.parse_args(base + ["--kv-dtype", "fp"]), cfg)
    on = serve.run_paged(serve.parse_args(base + ["--kv-dtype", "int8"]),
                         cfg)
    off = serve.run_paged(serve.parse_args(
        base + ["--kv-dtype", "int8", "--no-prefix-cache"]), cfg)
    assert on["batch_slots"] >= 1.8 * fp["batch_slots"]
    assert on["outputs"] == off["outputs"]          # bitwise within int8
    assert len(on["outputs"]) == 8                  # every request served
    # 8 requests through ~3 slots: later admissions must hit the trie
    assert on["prefix"]["hits"] > 0
    assert on["prefill_tokens_saved"] > 0
    assert on["prefill_tokens"] + on["prefill_tokens_saved"] \
        == off["prefill_tokens"]


@pytest.mark.parametrize("arch", ["deepseek_r1_671b", "qwen3_8b"])
@pytest.mark.parametrize("kv_dtype", QUANT_LAYOUTS)
def test_decode_step_quant_tracks_fp(kv_dtype, arch):
    """Model-level guard on the quantization error budget: teacher-forced
    paged decode logits under int8/fp8 stay within the measured budget of
    the fp paged path on the same prompts.  Two archs cover the two
    quantized cache layouts: deepseek MLA (single latent pool streamed by
    the quant Pallas kernels) and qwen3 GQA (K/V pools with PER-HEAD
    (scale, zp) granules through the gather-dequant path:
    attention._append_paged_kv / _gather_paged_kv /
    init_attention_cache_paged — without this leg the GQA quant branch
    has no automated coverage and could rot behind the MLA default)."""
    from repro.configs import get_config, reduced
    from repro.models import model
    atol = {"int8": 0.05, "fp8": 0.25}[kv_dtype]
    cfg = dataclasses.replace(reduced(get_config(arch)), moe=None)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, Sp, GEN = 2, 16, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Sp), 0,
                              cfg.vocab_size)
    forced = jax.random.randint(jax.random.PRNGKey(2), (GEN, B), 0,
                                cfg.vocab_size)
    layout = pc.layout_for(B, Sp + GEN, block_size=8)

    def run(kvd):
        bp = pc.BlockPool(layout, B)
        cache = model.init_paged_cache(cfg, layout, kv_dtype=kvd)
        for _ in range(B):
            bp.admit(0, Sp + GEN)
        table, lengths = bp.device_views()
        _, cache = model.prefill_chunk(params, cfg, cache, toks, table,
                                       lengths)
        for b in range(B):
            bp.extend(b, Sp)
        out = []
        for i in range(GEN):
            table, lengths = bp.device_views()
            lg, cache = model.decode_step(params, cfg, cache, forced[i],
                                          None, cache_layout="paged",
                                          block_table=table,
                                          lengths=lengths)
            for b in range(B):
                bp.append(b)
            out.append(np.asarray(lg))
        return out

    fp = run("fp")
    qt = run(kv_dtype)
    for a, b in zip(fp, qt, strict=True):
        np.testing.assert_allclose(b, a, atol=atol, rtol=0)
