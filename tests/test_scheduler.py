"""Scheduler subsystem validation (DESIGN.md §12): invariant-safe rollback
(BlockPool.truncate), the two-tier HBM/host swap path, preemption policy
(strict priority, victim order, backoff + idle kick, terminal refusal),
the preempted-then-released double-unref regression, a property test over
random admit/extend/append/truncate/swap_out/swap_in/release/evict
interleavings, and the end-to-end acceptance: a burst trace that
over-subscribes the pool 2x completes EVERY request via preemption/retry
with greedy outputs bitwise-identical to an uncontended run — under both
evacuation modes and under injected worker failures."""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.runtime import paged_cache as pc
from repro.runtime import scheduler as sch
from repro.runtime.fault_tolerance import FailureInjector, WorkerFailure
from repro.runtime.prefix_cache import PrefixCache


def _pool(bs=4, blocks=16, maxb=6, slots=3, host=0):
    layout = pc.PagedLayout(block_size=bs, num_blocks=blocks, max_blocks=maxb)
    return pc.BlockPool(layout, slots, host_blocks=host), PrefixCache(bs)


def _prefilled(bp, trie, tokens, gen=2):
    plen = len(tokens)
    slot = bp.admit(0, plen + gen)
    assert slot is not None
    bp.extend(slot, plen)
    if trie is not None:
        trie.insert(tokens, bp.block_ids(slot), bp)
    return slot


# ------------------------------------------------------- rollback primitive
def test_truncate_frees_tail_blocks_to_boundary():
    bp, _ = _pool(bs=4, blocks=16, maxb=6)
    s = bp.admit(0, 20)                      # 5 blocks reserved
    bp.extend(s, 18)
    free0 = bp.num_free
    assert bp.truncate(s, 9) == 2            # keep ceil(9/4)=3, free 2
    assert bp.num_free == free0 + 2
    assert int(bp.lengths[s]) == 9
    assert len(bp.block_ids(s)) == 3
    assert (bp.table[s, 3:] == pc.NULL_BLOCK).all()
    # budget shrank to the kept blocks' capacity: the slot may refill the
    # boundary block but not grow past it
    bp.extend(s, 3)                          # back to 12 = 3 * 4, allowed
    with pytest.raises(AssertionError, match="budget"):
        bp.append(s)
    bp.audit()
    bp.release(s)
    bp.check_conservation()
    assert bp.num_free == bp.layout.num_blocks - 1


def test_truncate_to_zero_frees_everything():
    bp, _ = _pool()
    s = bp.admit(0, 10)
    bp.extend(s, 10)
    assert bp.truncate(s, 0) == 3            # blocks_for(10) all freed
    assert int(bp.lengths[s]) == 0 and len(bp.block_ids(s)) == 0
    assert bp.active[s]                      # truncate is NOT release
    bp.check_conservation()


def test_truncate_length_only_keeps_reservation():
    """free_blocks=False is the speculative-decoding rollback: lengths
    rewinds, the reservation survives, decoding continues allocation-free."""
    bp, _ = _pool()
    s = bp.admit(0, 12)
    bp.extend(s, 10)
    free0 = bp.num_free
    assert bp.truncate(s, 6, free_blocks=False) == 0
    assert bp.num_free == free0              # nothing freed
    assert int(bp.lengths[s]) == 6
    for _ in range(6):                       # rejected rows re-append fine
        bp.append(s)
    assert int(bp.lengths[s]) == 12
    bp.audit()


def test_truncate_spares_shared_tail_blocks():
    """A trie-cached block dropped by truncate survives at the trie's
    reference — same unref path as release, conservation at every step."""
    bp, trie = _pool(bs=4)
    toks = np.arange(8)
    s = _prefilled(bp, trie, toks, gen=4)
    cached = [int(b) for b in bp.block_ids(s)[:2]]
    bp.truncate(s, 0)
    assert all(bp.ref[b] == 1 for b in cached)       # trie still holds them
    chain, matched = trie.match(np.asarray(list(toks) + [9]))
    assert chain == cached and matched == 8          # still matchable
    bp.check_conservation()


# --------------------------------------------------------- host swap tier
def test_swap_roundtrip_accounting():
    bp, _ = _pool(bs=4, blocks=16, maxb=6, slots=2, host=8)
    s = bp.admit(0, 20)
    bp.extend(s, 10)                         # 3 written of 5 reserved blocks
    rec = bp.swap_out(s, "r0")
    assert rec is not None
    assert len(rec.host_ids) == 3 and rec.n_tokens == 10 and rec.budget == 20
    assert bp.host_free == 5
    assert not bp.active[s]                  # slot fully released
    assert bp.num_free == bp.layout.num_blocks - 1
    bp.check_conservation()
    got = bp.swap_in("r0")
    assert got is not None
    slot, cow, rec2 = got
    assert rec2 is rec and cow == []
    assert int(bp.lengths[slot]) == 10       # restored rows accounted
    assert bp.budget(slot) == 20       # original budget re-reserved
    assert bp.host_free == 8                 # host ids returned
    assert "r0" not in bp.swapped
    bp.check_conservation()


def test_swap_out_refuses_when_host_tier_full():
    bp, _ = _pool(bs=4, host=1)
    s = bp.admit(0, 10)
    bp.extend(s, 10)                         # 3 blocks > 1 host block
    assert not bp.can_swap_out(s)
    assert bp.swap_out(s, "r0") is None
    assert bp.active[s] and bp.host_free == 1    # untouched on refusal
    bp.check_conservation()


def test_swap_in_refusal_leaves_record_untouched():
    bp, _ = _pool(bs=4, blocks=7, maxb=6, slots=2, host=8)  # 6 real blocks
    s = bp.admit(0, 20)                      # 5 of 6 blocks
    bp.extend(s, 8)
    assert bp.swap_out(s, "r0") is not None
    hog = bp.admit(0, 20)                    # re-take the capacity
    assert hog is not None
    assert bp.swap_in("r0") is None          # refusal: 5 needed, 1 free
    assert "r0" in bp.swapped and bp.host_free == 6
    bp.check_conservation()
    bp.release(hog)
    assert bp.swap_in("r0") is not None      # retry succeeds
    bp.check_conservation()


def test_preempted_then_released_does_not_double_unref():
    """REGRESSION (ISSUE 6 satellite): a preempted-then-cancelled request
    whose prompt blocks are trie-cached dropped its device references ONCE
    at swap_out — cancelling while the swap tier holds the copy must free
    HOST ids only.  A second device unref would free trie-cached blocks
    out from under other requests' future matches."""
    bp, trie = _pool(bs=4, host=8)
    toks = np.arange(8)
    s = _prefilled(bp, trie, toks, gen=4)
    cached = [int(b) for b in bp.block_ids(s)[:2]]
    sched = sch.Scheduler(bp, trie, cfg=sch.SchedulerConfig(
        preemption="swap"))
    r = sch.Request(id=0, prompt=toks, gen=4)
    r.state, r.slot, r.decoding, r.pf_pos = sch.RUNNING, s, True, 8
    sched.by_slot[s] = r
    sched.preempt(r, tick=0)
    assert r.state == sch.PREEMPTED and 0 in bp.swapped
    assert all(bp.ref[b] == 1 for b in cached)   # trie's ref survives swap
    ref_snapshot = bp.ref.copy()
    sched.cancel(r)                              # released while preempted
    assert r.state == sch.DONE and 0 not in bp.swapped
    assert bp.host_free == 8                     # host ids returned...
    np.testing.assert_array_equal(bp.ref, ref_snapshot)  # ...device refs
    assert all(bp.ref[b] == 1 for b in cached)   # NOT touched again
    chain, matched = trie.match(np.asarray(list(toks) + [9]))
    assert chain == cached and matched == 8      # cache still serves hits
    bp.check_conservation()


def test_audit_catches_out_of_band_table_scribble():
    bp, _ = _pool()
    s = bp.admit(0, 8)
    bp.audit()                               # clean
    bp.table[s, 4] = 3                       # scribble beyond the chain
    with pytest.raises(AssertionError, match="stale ids"):
        bp.audit()
    bp.table[s, 4] = pc.NULL_BLOCK
    bp.table[s, 0] = 9                       # table/chain disagreement
    with pytest.raises(AssertionError, match="disagrees"):
        bp.audit()


# ------------------------------------------------------- scheduler policy
def _mk_sched(slots=2, blocks=9, maxb=4, bs=4, host=0, preemption="recompute",
              prefix=False, **cfg):
    bp, trie = _pool(bs=bs, blocks=blocks, maxb=maxb, slots=slots, host=host)
    sched = sch.Scheduler(bp, trie if prefix else None,
                          cfg=sch.SchedulerConfig(preemption=preemption,
                                                  **cfg))
    return bp, sched


def _req(rid, priority=0, plen=8, gen=8, arrival=0):
    return sch.Request(id=rid, prompt=np.arange(plen), gen=gen,
                       priority=priority, arrival=arrival)


def test_preemption_strictly_lower_priority_only():
    """Equals never preempt each other (the livelock guard); a higher
    class evicts the lowest class first and the victim requeues ahead of
    same-class WAITING requests."""
    bp, sched = _mk_sched()                  # 2 slots x 4 blocks: 2 fit
    r0, r1 = _req(0, priority=1), _req(1, priority=2)
    sched.add(r0)
    sched.add(r1)
    sched.admit(0)
    assert r0.state == r1.state == sch.RUNNING
    same = _req(2, priority=2)               # equal to the worst victim
    sched.add(same)
    sched.admit(1)
    assert same.state == sch.WAITING         # no preemption among equals
    assert sched.counters["refusals"] == 1
    high = _req(3, priority=0)
    sched.add(high)
    sched.admit(2)
    assert high.state == sch.RUNNING         # preempted the class-2 victim
    assert r1.state == sch.PREEMPTED and r1.preemptions == 1
    assert r0.state == sch.RUNNING           # class 1 survives class 0's ask
    assert sched.counters["preempts_recompute"] == 1
    bp.check_conservation()


def test_victim_selection_lowest_priority_then_shortest_progress():
    bp, sched = _mk_sched(slots=3, blocks=13)
    a, b, c = _req(0, priority=2), _req(1, priority=2), _req(2, priority=1)
    for r in (a, b, c):
        sched.add(r)
    sched.admit(0)
    bp.extend(a.slot, 6)                     # a has made more progress
    bp.extend(b.slot, 2)
    bp.extend(c.slot, 8)
    sched.add(_req(3, priority=0, plen=8))
    sched.admit(1)
    assert b.state == sch.PREEMPTED          # lowest class, least progress
    assert a.state == sch.RUNNING and c.state == sch.RUNNING


def test_preempted_requeues_ahead_of_waiting_peers():
    bp, sched = _mk_sched()
    v = _req(0, priority=1)
    sched.add(v)
    sched.admit(0)
    sched.preempt(v, tick=0)                 # forced (e.g. fault path)
    w = _req(1, priority=1)                  # same class, WAITING
    sched.add(w)
    sched.admit(1)
    assert v.state == sch.RUNNING            # PREEMPTED sorts first
    assert sched.counters["restores_recompute"] == 1
    assert w.state == sch.RUNNING            # room for both afterwards


def test_backoff_and_idle_kick():
    bp, sched = _mk_sched(slots=1, backoff_cap=8)
    r0 = _req(0, gen=8)
    sched.add(r0)
    sched.admit(0)
    r1 = _req(1, priority=0)                 # equal class: cannot preempt
    sched.add(r1)
    for t in (1, 2):
        sched.admit(t)
    assert r1.attempts == 2 and r1.next_try == 2 + 2   # 1, then 2 ticks
    assert 1 in sched.refused_ids
    # pool drains: nothing is running, r1 still backing off — the idle
    # kick clears the backoff instead of idling a non-empty queue
    r0.remaining = 0
    sched.finish(r0)
    sched.admit(3)
    assert r1.state == sch.RUNNING and sched.counters["idle_kicks"] == 1


def test_terminal_refusal_raises_on_impossible_request():
    bp, sched = _mk_sched(slots=1)
    sched.add(_req(0, plen=20, gen=10))      # 30 tokens > max_len 16
    with pytest.raises(RuntimeError, match="can never fit"):
        sched.admit(0)


def test_recompute_restore_pins_prompt_chain():
    """While a recompute victim is out, its cached prompt chain is pinned
    (evicted last); restore unpins so the supply is not leaked."""
    bp, trie = _pool(bs=4, blocks=16, maxb=6, slots=2)
    sched = sch.Scheduler(bp, trie)
    toks = np.arange(8)
    donor = sch.Request(id=0, prompt=toks, gen=4)
    sched.add(donor)
    sched.admit(0)
    bp.extend(donor.slot, 8)
    trie.insert(toks, bp.block_ids(donor.slot), bp)
    donor.decoding, donor.pf_pos = True, 8
    sched.preempt(donor, tick=0)
    # the pinned chain is the MATCHABLE prefix (match caps at plen-1, so
    # the final prompt block re-prefills regardless): one block here
    assert donor.pinned == [int(trie.peek_chain([0, 1, 2, 3])[0])]
    assert trie.stats()["pinned_blocks"] == 1
    sched.admit(1)                               # restore
    assert donor.state == sch.RUNNING
    assert donor.pinned is None and trie.stats()["pinned_blocks"] == 0
    assert donor.matched == 4                    # trie served the re-match
    assert donor.replay == sch.deque()           # nothing delivered yet


def test_prefill_quota_shrinks_under_itl_pressure():
    bp, sched = _mk_sched(slo_itl_ms=10.0)
    assert sched.prefill_quota(32) == 32     # no samples yet: full share
    sched._itl_recent.extend([5.0] * 16)
    assert sched.prefill_quota(32) == 32     # under budget: full share
    sched._itl_recent.extend([40.0] * 64)    # p50 4x over budget
    assert sched.prefill_quota(32) == 8      # proportional, floored at 1
    assert sched.prefill_quota(1) == 1


def test_failure_injector_from_rate():
    inj = FailureInjector.from_rate(0.25, horizon=20)
    fails = []
    for t in range(20):
        try:
            inj.check(t)
        except WorkerFailure:
            fails.append(t)
    assert fails == [4, 8, 12, 16]


# ------------------------------------------------- property: conservation
def _drive(seed: int) -> None:
    """Random interleaving of admit/extend/append/truncate/swap_out/
    swap_in/release/evict ops; after every op the pool must conserve
    blocks (free + slot-owned + trie-cached partition the device pool,
    free + swap-record ids partition the host tier) and refcounts stay
    non-negative.  Truncation rolls back GENERATED tokens only — the
    scheduler's real rollback shapes (speculative rewind, preempt via
    swap_out/release) — since re-prefilling trie-inserted rows in place
    would be a COW violation by design."""
    layout = pc.PagedLayout(block_size=2, num_blocks=14, max_blocks=6)
    slots = 3
    bp = pc.BlockPool(layout, slots, host_blocks=8)
    trie = PrefixCache(layout.block_size)
    rng = np.random.default_rng(seed)
    prompts = [None] * slots
    pf = [0] * slots
    gen_left = [0] * slots
    swapped_meta = {}                        # key -> (prompt, gen_left)
    next_key = [0]

    def check():
        bp.check_conservation()
        free = bp.free_ids()
        owned = set()
        for s in range(slots):
            if bp.active[s]:
                owned |= set(int(x) for x in bp.block_ids(s))
        cached = trie.cached_block_ids()
        assert not free & (owned | cached)
        assert free | owned | cached == set(range(1, layout.num_blocks))

    for _ in range(160):
        op = int(rng.integers(0, 8))
        if op == 0 and bp.free_slots():                       # admit/share
            plen = int(rng.integers(1, 9))
            glen = int(rng.integers(1, 4))
            total = plen + glen
            if total > layout.max_len:
                continue
            toks = rng.integers(0, 3, size=plen)              # tiny vocab:
            chain, matched = trie.match(toks)                 # real hits
            while not bp.can_admit(total, n_shared=len(chain)):
                if trie.evict_lru(bp, protect=frozenset(chain)) is None:
                    break
            if chain:
                got = bp.admit_shared(matched, total, chain)
            else:
                s = bp.admit(0, total)
                got = None if s is None else (s, [])
            if got is not None:
                s, cow = got
                assert not cow                # trie matches: block-aligned
                prompts[s], pf[s], gen_left[s] = toks, matched, glen
        elif op == 1:                                          # extend
            cands = [s for s in range(slots) if bp.active[s]
                     and prompts[s] is not None and pf[s] < len(prompts[s])]
            if cands:
                s = cands[int(rng.integers(len(cands)))]
                c = int(rng.integers(1, len(prompts[s]) - pf[s] + 1))
                bp.extend(s, c)
                pf[s] += c
                if pf[s] == len(prompts[s]):   # prompt done: cache it
                    trie.insert(prompts[s], bp.block_ids(s), bp)
        elif op == 2:                                          # append
            cands = [s for s in range(slots) if bp.active[s]
                     and prompts[s] is not None
                     and pf[s] == len(prompts[s]) and gen_left[s] > 0
                     and bp.lengths[s] < bp.budget(s)]
            if cands:
                s = cands[int(rng.integers(len(cands)))]
                bp.append(s)
                gen_left[s] -= 1
        elif op == 3:                                          # truncate
            cands = [s for s in range(slots) if bp.active[s]
                     and prompts[s] is not None
                     and bp.lengths[s] > pf[s]]
            if cands:                          # roll back generated rows
                s = cands[int(rng.integers(len(cands)))]
                lo, hi = pf[s], int(bp.lengths[s])
                n = int(rng.integers(lo, hi + 1))
                if rng.integers(2):            # spec-decode shape: length
                    bp.truncate(s, n, free_blocks=False)
                    for _ in range(int(bp.lengths[s]),
                                   min(hi, bp.budget(s))):
                        bp.append(s)           # rows re-append in place
                else:
                    rolled = hi - n
                    bp.truncate(s, n)
                    gen_left[s] += rolled      # rolled-back budget returns
        elif op == 4:                                          # swap_out
            cands = [s for s in range(slots) if bp.active[s]
                     and prompts[s] is not None and bp.can_swap_out(s)]
            if cands:
                s = cands[int(rng.integers(len(cands)))]
                key = next_key[0]
                next_key[0] += 1
                rec = bp.swap_out(s, key)
                assert rec is not None
                swapped_meta[key] = (prompts[s], gen_left[s])
                prompts[s] = None
        elif op == 5 and bp.swapped:                           # swap_in
            keys = sorted(bp.swapped)
            key = keys[int(rng.integers(len(keys)))]
            rec = bp.swapped[key]
            toks, gl = swapped_meta[key]
            chain, matched = trie.match(toks, record=False)
            if matched > rec.budget:
                chain, matched = [], 0
            while not bp.can_admit(rec.budget, n_shared=len(chain)):
                if trie.evict_lru(bp, protect=frozenset(chain)) is None:
                    break
            got = bp.swap_in(key, chain, matched)
            if got is not None:
                s, cow, rec = got
                assert not cow
                del swapped_meta[key]
                n_eff = max(matched, rec.n_tokens)
                prompts[s] = toks
                pf[s] = min(n_eff, len(toks))
                gen_left[s] = gl
                if pf[s] == len(toks):
                    trie.insert(toks, bp.block_ids(s), bp)
        elif op == 6 and bp.swapped:                           # cancel
            keys = sorted(bp.swapped)
            key = keys[int(rng.integers(len(keys)))]
            bp.swap_free(key)
            del swapped_meta[key]
        elif op == 7:                                          # release
            cands = [s for s in range(slots) if bp.active[s]]
            if cands:
                s = cands[int(rng.integers(len(cands)))]
                bp.release(s)
                prompts[s] = None
            else:
                trie.evict_lru(bp)
        check()
        bp.audit()


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_rollback_swap_conservation_property(seed):
        _drive(seed)
else:
    def test_rollback_swap_conservation_property():
        """Deterministic stand-in for the hypothesis property (keeps the
        tier-1 skip count flat when hypothesis is absent): seeded random
        interleavings through the same driver."""
        for seed in range(25):
            _drive(seed)


# ---------------------------------------------------------- end to end
def _serve(argv, cfg):
    from repro.launch import serve
    return serve.run_paged(serve.parse_args(argv), cfg)


def _no_moe_cfg():
    from repro.configs import get_config, reduced
    return dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                               moe=None)


CONTENDED = ["--reduced", "--batch", "2", "--prompt", "24", "--gen", "8",
             "--requests", "6", "--page-size", "8", "--prefill-chunk", "8",
             "--cache-layout", "paged", "--priority-classes", "3",
             "--arrival-rate", "0.25", "--trace", "burst",
             "--burst-size", "3", "--retry-backoff", "4", "--paranoia", "1"]


def test_serve_preemption_bitwise_both_modes():
    """ACCEPTANCE (ISSUE 6): a burst trace over-subscribing the pool ~2x
    (6 requests x up to 32 tokens through 2 fp slots) completes every
    request with zero permanent refusals, and greedy outputs are BITWISE
    identical to an uncontended run — for swap AND recompute evacuation.
    MoE is dropped because dropless routing mixes tokens across slots and
    contended runs batch different slot compositions per step; the
    paranoia sweep audits pool invariants every tick throughout."""
    cfg = _no_moe_cfg()
    calm = _serve(CONTENDED[:2] + ["8"] + CONTENDED[3:], cfg)  # batch 8
    rec = _serve(CONTENDED + ["--preemption", "recompute"], cfg)
    swp = _serve(CONTENDED + ["--preemption", "swap"], cfg)
    assert calm["sched"]["preemptions"] == 0          # truly uncontended
    for res in (rec, swp):
        assert len(res["outputs"]) == 6               # zero PERMANENT
        assert res["outputs"] == calm["outputs"]      # refusals, bitwise
        assert res["tokens_served"] == calm["tokens_served"]
    if rec["kv_dtype"] == "fp":
        # quantized legs expand batch_slots under the same byte budget and
        # may never need to preempt; the fp leg must actually contend
        assert rec["sched"]["preemptions"] > 0
        assert rec["sched"]["preempts_recompute"] > 0
        assert swp["sched"]["preempts_swap"] > 0
        assert swp["sched"]["restores_swap"] > 0
        assert rec["refusals"] > 0                    # transient only
    # per-class latency tails exist for every class that finished work
    for res in (rec, swp):
        for cls, st_ in res["classes"].items():
            assert st_["n"] > 0 and st_["ttft_p99_ms"] >= st_["ttft_p50_ms"]


def test_serve_fault_injection_bitwise():
    """Satellite (ISSUE 6): deterministic mid-decode worker failures under
    --fault-rate requeue the victim through the recompute path, the
    heartbeat registry notices each missed beat, and the run completes
    with outputs bitwise-identical to the unfaulted run."""
    cfg = _no_moe_cfg()
    base = ["--reduced", "--batch", "2", "--prompt", "24", "--gen", "8",
            "--requests", "4", "--page-size", "8", "--prefill-chunk", "8",
            "--cache-layout", "paged", "--paranoia", "1"]
    clean = _serve(base, cfg)
    fault = _serve(base + ["--fault-rate", "0.05"], cfg)
    assert fault["outputs"] == clean["outputs"]       # bitwise identical
    assert len(fault["outputs"]) == 4
    assert fault["worker_restarts"] == fault["sched"]["failures"]
    if fault["kv_dtype"] == "fp":
        # the quantized CI leg widens batch_slots, finishes before the
        # first scheduled fault, and (correctly) injects nothing — only
        # the fp leg is guaranteed to still be decoding at the fault tick
        assert fault["sched"]["failures"] > 0
        assert fault["replayed_tokens"] > 0           # replay actually ran
