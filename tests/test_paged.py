"""Paged KV-cache validation (DESIGN.md §8): paged-vs-dense oracle
equivalence across block sizes / split counts / ragged lengths straddling
block boundaries, the bitwise dense↔paged contract at block-aligned
lengths, allocator reuse-after-release + out-of-blocks admission refusal,
and the continuous-batching serve loop end to end.  All Pallas runs are
interpret=True on CPU; tolerances match tests/test_splitkv.py."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.etap import decode_attention_paged, etap_decode_paged_xla
from repro.kernels.etap import ops as etap_ops
from repro.kernels.etap.ref import etap_decode_ref
from repro.kernels.etap.schedule import paged_split_geometry, plan_splits_paged
from repro.runtime import paged_cache as pc

RNG = np.random.default_rng(11)


def _mk(B, H, Dk, Dv, S, *, lengths):
    q = jnp.asarray(RNG.normal(size=(B, H, Dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Dv)), jnp.float32)
    return q, k, v, jnp.asarray(lengths, jnp.int32)


def _paged(dense, lengths, page, *, spare=4):
    layout = pc.layout_for(dense.shape[0], dense.shape[1], block_size=page,
                           spare_blocks=spare)
    pool, bp = pc.dense_to_paged(dense, np.asarray(lengths), layout)
    return pool, bp


def _rmse(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.sqrt(np.mean((a - b) ** 2)))


# lengths deliberately straddle page boundaries for both page sizes:
# one mid-page, one exactly on a 16-boundary, one one-past-a-64-boundary,
# one at the full context.
S = 320
RAGGED = [7, 64, 65, 320]


@pytest.mark.parametrize("page", [16, 64])
@pytest.mark.parametrize("n_splits", [1, 4])
def test_paged_separate_v_vs_ref(page, n_splits):
    q, k, v, L = _mk(4, 8, 64, 64, S, lengths=RAGGED)
    scale = 64 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    k_pool, bp = _paged(k, RAGGED, page)
    v_pool, _ = _paged(v, RAGGED, page)
    table, lengths = bp.device_views()
    out = etap_ops.etap_decode_paged_splitkv(q, k_pool, v_pool, table,
                                             lengths, scale=scale,
                                             n_splits=n_splits)
    assert _rmse(out, ref) <= 1e-4
    # same geometry through the gather-based XLA path
    out_x = etap_decode_paged_xla(q, k_pool, v_pool, table, lengths,
                                  scale=scale)
    assert _rmse(out_x, ref) <= 1e-4


@pytest.mark.parametrize("page", [16, 64])
@pytest.mark.parametrize("n_splits", [1, 4])
def test_paged_mla_fused_vs_ref(page, n_splits):
    q, kv, _, L = _mk(4, 8, 96, 96, S, lengths=RAGGED)
    dv = 64                                  # V = first 64 latent columns
    scale = 96 ** -0.5
    ref = etap_decode_ref(q, kv, kv[..., :dv], L, scale=scale)
    kv_pool, bp = _paged(kv, RAGGED, page)
    table, lengths = bp.device_views()
    out = etap_ops.etap_decode_mla_paged_splitkv(q, kv_pool, dv, table,
                                                 lengths, scale=scale,
                                                 n_splits=n_splits)
    assert _rmse(out, ref) <= 1e-4


@pytest.mark.parametrize("page", [16, 64])
def test_paged_bitwise_dense_at_block_aligned(page):
    """At block-aligned lengths with n_splits=1, the paged kernel walks the
    same blocks in the same order as the dense kernel at block == page —
    the block table only redirects the DMA source, so outputs are BITWISE
    equal (acceptance criterion)."""
    aligned = [page, 2 * page, 4 * page, S]
    q, k, v, L = _mk(4, 8, 64, 64, S, lengths=aligned)
    scale = 64 ** -0.5
    k_pool, bp = _paged(k, aligned, page)
    v_pool, _ = _paged(v, aligned, page)
    table, lengths = bp.device_views()
    dense = etap_ops.etap_decode(q, k, v, L, scale=scale, block=page)
    paged = etap_ops.etap_decode_paged_splitkv(q, k_pool, v_pool, table,
                                               lengths, scale=scale,
                                               n_splits=1)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))
    # and the gather-based XLA paged path is bitwise the dense XLA loop
    from repro.core.etap import etap_decode_xla
    np.testing.assert_array_equal(
        np.asarray(etap_decode_paged_xla(q, k_pool, v_pool, table, lengths,
                                         scale=scale)),
        np.asarray(etap_decode_xla(q, k, v, L, scale=scale, block=page)))


def test_paged_shuffled_table_matches_logical_order():
    """The kernels must follow the TABLE, not physical pool order: serve a
    sequence whose blocks are deliberately scattered through the pool."""
    page, n = 16, 8
    q, k, v, L = _mk(1, 8, 64, 64, n * page, lengths=[n * page])
    scale = 64 ** -0.5
    perm = RNG.permutation(np.arange(1, n + 1)).astype(np.int32)
    pool_k = np.zeros((n + 1, page, 64), np.float32)
    pool_v = np.zeros((n + 1, page, 64), np.float32)
    pool_k[perm] = np.asarray(k[0]).reshape(n, page, 64)
    pool_v[perm] = np.asarray(v[0]).reshape(n, page, 64)
    out = etap_ops.etap_decode_paged(q, jnp.asarray(pool_k),
                                     jnp.asarray(pool_v), perm[None, :],
                                     L, scale=scale)
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    assert _rmse(out, ref) <= 1e-4


def test_decode_attention_paged_modes_agree():
    """Unified paged entry point: kernel / XLA / standard-baseline paths
    agree on the same paged cache (ragged lengths)."""
    q, k, v, L = _mk(3, 8, 64, 32, 256, lengths=[5, 128, 250])
    scale = 64 ** -0.5
    ref = etap_decode_ref(q, k, v, L, scale=scale)
    k_pool, bp = _paged(k, [5, 128, 250], 64)
    v_pool, _ = _paged(v, [5, 128, 250], 64)
    table, lengths = bp.device_views()
    for kw in (dict(mode="etap", use_kernels=True),
               dict(mode="etap", use_kernels=False),
               dict(mode="etap", use_kernels=False, n_splits=4),
               dict(mode="standard", use_kernels=False)):
        out = decode_attention_paged(q, k_pool, v_pool, table, lengths,
                                     scale=scale, **kw)
        assert _rmse(out, ref) <= 1e-4, kw


# ---------------------------------------------------------------- allocator
def test_allocator_reuse_after_release():
    layout = pc.PagedLayout(block_size=16, num_blocks=7, max_blocks=3)
    bp = pc.BlockPool(layout, 2)
    s0 = bp.admit(40, 48)                    # 3 blocks
    s1 = bp.admit(30, 48)                    # 3 blocks -> pool exhausted
    assert s0 == 0 and s1 == 1 and bp.num_free == 0
    ids0 = set(bp.block_ids(s0))
    bp.release(s0)
    assert bp.num_free == 3
    assert not bp.active[s0]
    assert (bp.table[s0] == pc.NULL_BLOCK).all()
    s2 = bp.admit(10, 48)
    assert s2 == s0                          # slot recycled
    assert set(bp.block_ids(s2)) == ids0     # blocks recycled
    # no double allocation: s1 and s2 own disjoint blocks
    assert not (set(bp.block_ids(s1)) & set(bp.block_ids(s2)))


def test_allocator_out_of_blocks_admission_refusal():
    layout = pc.PagedLayout(block_size=16, num_blocks=5, max_blocks=4)
    bp = pc.BlockPool(layout, 4)
    assert bp.admit(48, 48) == 0             # takes 3 of 4 blocks
    assert not bp.can_admit(32)              # 2 blocks needed, 1 free
    assert bp.admit(20, 32) is None          # refusal, not an error
    assert bp.admit(70, 70) is None          # > max_len always refused
    assert bp.admit(9, 16) == 1              # 1 block still fits
    bp.release(0)
    assert bp.can_admit(48)                  # refusal clears after release


def test_shared_admission_midblock_cow_refusal_boundary():
    """ISSUE 5 satellite: when a shared prefix ends MID-block, the chain's
    partial tail block is NOT mapped — its logical position needs a fresh
    eager-COW copy target, which must be charged to the free list BEFORE
    admission succeeds.  At exactly-one-block-short occupancy the
    accounting must refuse; counting ``len(chain)`` as shared (the old
    serve-loop arithmetic) would say yes here and strand the request
    between a lying can_admit and a refusing admit_shared."""
    bs = 16
    # donor chain: 3 blocks holding 40 tokens (third block PARTIAL at 8)
    layout = pc.PagedLayout(block_size=bs, num_blocks=1 + 3 + 2,
                            max_blocks=5)
    bp = pc.BlockPool(layout, 3)
    donor = bp.admit(40, 40)
    chain = [int(b) for b in bp.block_ids(donor)]
    assert len(chain) == 3
    # new request: same 40-token prefix + budget to 64 tokens = 4 logical
    # blocks; 2 full shared blocks map, so it needs 4 - 2 = 2 fresh blocks
    # (one of them the COW copy of the partial third block) but only 2
    # remain... take one away to sit exactly one block short.
    filler = bp.admit(bs, bs)                # consumes 1 block -> 1 free
    n_full = 40 // bs                        # 2 FULL shared blocks
    assert not bp.can_admit(64, n_shared=n_full)       # 2 needed, 1 free
    # the buggy arithmetic (len(chain) == 3 shared) would claim it fits:
    assert bp.can_admit(64, n_shared=len(chain))
    # and admit_shared, which counts full blocks itself, refuses — the
    # predicate and the admission must agree at the boundary
    assert bp.admit_shared(40, 64, chain) is None
    bp.check_conservation()
    # with the missing block back, the same admission succeeds and returns
    # the (partial donor block -> fresh private block) COW pair
    bp.release(filler)
    assert bp.can_admit(64, n_shared=n_full)
    slot, cow = bp.admit_shared(40, 64, chain)
    assert len(cow) == 1
    src, dst = cow[0]
    assert src == chain[2] and dst not in chain
    # the mapped prefix shares refcounts; the COW target is private
    assert all(int(bp.ref[b]) == 2 for b in chain[:2])
    assert int(bp.ref[src]) == 1 and int(bp.ref[dst]) == 1
    bp.check_conservation()


def test_append_rows_across_block_boundary():
    """Token-by-token appends crossing a page boundary land in the right
    (block, slot) cells; inactive slots write only the null block."""
    layout = pc.PagedLayout(block_size=4, num_blocks=6, max_blocks=2)
    bp = pc.BlockPool(layout, 2)
    slot = bp.admit(3, 8)
    assert slot == 0                         # slot 1 stays inactive
    pool = jnp.zeros((6, 4, 2))
    ref = np.zeros((8, 2), np.float32)
    for t in range(3, 8):
        table, lengths = bp.device_views()
        row = jnp.full((2, 2), float(t))
        pool = pc.append_rows(pool, table, lengths, row)
        ref[t] = t
        bp.append(0)
    dense = pc.gather_blocks(pool, bp.device_views()[0])
    np.testing.assert_array_equal(np.asarray(dense[0]), ref)
    # slot 1 (inactive, all-null table) only ever wrote the null block:
    # every block that is neither null nor owned by slot 0 is untouched
    untouched = sorted(set(range(6)) - {pc.NULL_BLOCK}
                       - set(bp.block_ids(0).tolist()))
    np.testing.assert_array_equal(np.asarray(pool[np.asarray(untouched)]),
                                  np.zeros((len(untouched), 4, 2)))


def test_release_nulls_whole_row_and_is_unreachable_from_device_views():
    """Release audit: a released slot's table row is fully nulled at ROW
    granularity — no stale physical id at any column — so no stale mapping
    can reach a kernel through device_views(); and device views taken
    BEFORE the release are copies, immune to the mutation."""
    layout = pc.PagedLayout(block_size=4, num_blocks=8, max_blocks=4)
    bp = pc.BlockPool(layout, 2)
    s = bp.admit(10, 12)                     # 3 of 4 table columns used
    table_before, _ = bp.device_views()
    assert (np.asarray(table_before[s][:3]) != pc.NULL_BLOCK).all()
    bp.release(s)
    assert (bp.table[s] == pc.NULL_BLOCK).all()
    table, lengths = bp.device_views()
    assert (np.asarray(table[s]) == pc.NULL_BLOCK).all()
    assert int(lengths[s]) == 0
    # a view taken pre-release is an owned copy: still the old ids (the
    # async-dispatch contract), while the live table shows only nulls
    assert (np.asarray(table_before[s][:3]) != pc.NULL_BLOCK).all()
    bp.check_conservation()


# ---------------------------------------------------------------- scheduler
def test_paged_split_geometry_page_granular():
    for nb in (1, 3, 7, 16):
        for n in (1, 2, 4, 8):
            n_eff, npb, padded = paged_split_geometry(nb, n)
            assert padded % n_eff == 0 and padded >= nb
            assert npb * n_eff == padded
            # effective count: every split owns >= 1 REAL table column
            assert 1 <= n_eff <= min(n, nb)
            assert (n_eff - 1) * npb < nb
    plan = plan_splits_paged(1, 1024, 64, 16, 512)
    assert plan.block == 64                  # split unit is the page
    assert plan.n_splits * plan.nb_per_split >= 1024   # plan covers the table
    # long context / small batch does split; page-sized context doesn't
    assert plan.n_splits > 1
    assert plan_splits_paged(16, 1, 64, 16, 512).n_splits == 1


# ------------------------------------------------------------ end to end
def test_decode_step_paged_matches_dense():
    """cache_layout="paged" is a layout change, not a model change:
    teacher-forced per-step logits match the dense path to float-noise
    tolerance on the same prompts (reduced deepseek — the paper's arch).
    Teacher-forced because greedy streams amplify near-tie argmax flips
    between summation orders into different suffixes; MoE is dropped
    because the top-k router is DISCONTINUOUS — float-noise differences
    between the two layouts' summation orders can flip an expert at a
    near-tie gate and produce an O(1e-2) logit jump that has nothing to do
    with the cache layout under test.

    Under REPRO_KV_DTYPE=int8/fp8 (the CI quantized leg) the paged cache
    stores codes, so the comparison against the fp dense path loosens to
    the layout's measured quantization-error budget instead of float
    noise — the test then proves the quantized serving path tracks the fp
    model, not that it equals it."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.models import model

    kv_dtype = os.environ.get("REPRO_KV_DTYPE", "fp")
    atol = {"fp": 1e-4, "int8": 0.05, "fp8": 0.2}[kv_dtype]
    cfg = dataclasses.replace(reduced(get_config("deepseek_r1_671b")),
                              moe=None)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S, GEN = 2, 24, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    forced = jax.random.randint(jax.random.PRNGKey(2), (GEN, B), 0,
                                cfg.vocab_size)
    _, cache, pos = model.prefill(params, cfg, {"tokens": toks},
                                  max_len=S + GEN)
    dense_lg = []
    for i in range(GEN):
        lg, cache = model.decode_step(params, cfg, cache, forced[i],
                                      pos + i, kv_splits=1)
        dense_lg.append(lg)

    layout = pc.layout_for(B, S + GEN, block_size=16)
    bp = pc.BlockPool(layout, B)
    paged = model.init_paged_cache(cfg, layout, kv_dtype=kv_dtype)
    for b in range(B):
        slot = bp.admit(0, S + GEN)          # cold: chunked prefill fills it
        assert slot == b
    for lo, hi in ((0, 16), (16, S)):        # aligned + unaligned chunks
        table, lengths = bp.device_views()
        _, paged = model.prefill_chunk(params, cfg, paged, toks[:, lo:hi],
                                       table, lengths)
        for b in range(B):
            bp.extend(b, hi - lo)
    for i in range(GEN):
        table, lengths = bp.device_views()
        lg, paged = model.decode_step(params, cfg, paged, forced[i], None,
                                      kv_splits=1, cache_layout="paged",
                                      block_table=table, lengths=lengths)
        for b in range(B):
            bp.append(b)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(dense_lg[i]),
                                   atol=atol, rtol=1e-4)


def test_init_paged_cache_rejects_non_attention():
    from repro.configs import get_config, reduced
    from repro.models import model
    cfg = reduced(get_config("falcon_mamba_7b"))
    with pytest.raises(ValueError, match="attention-only"):
        model.init_paged_cache(cfg, pc.PagedLayout(16, 4, 2))


def test_continuous_batching_serve_loop():
    """Ragged requests join and leave the batch; every request gets exactly
    its budgeted tokens; throughput accounting counts true tokens served
    (NOT batch * gen); out-of-pool requests wait, none are dropped."""
    from repro.launch import serve

    args = serve.parse_args([
        "--reduced", "--batch", "2", "--prompt", "24", "--gen", "6",
        "--requests", "5", "--page-size", "8", "--cache-layout", "paged"])
    res = serve.run(args)
    assert len(res["outputs"]) == 5          # every request served
    gens = {i: len(v) for i, v in res["outputs"].items()}
    assert res["tokens_served"] == sum(gens.values())
    assert all(n in (3, 6) for n in gens.values())  # the two gen buckets
    # ragged stream through the slots must beat the naive fixed-batch
    # count (batch_slots: quantized layouts admit MORE than --batch under
    # the same byte budget, so the reported count is the bound)
    assert res["steps"] >= max(gens.values())
    assert res["tokens_served"] <= res["batch_slots"] * res["steps"]
