"""MoE dispatch: sort-based assignment vs a dense reference, capacity/drop
semantics, dropless serving mode, aux losses, and hypothesis invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config, reduced
from repro.models import moe


def _cfg(E=4, k=2, cf=8.0):
    base = reduced(get_config("dbrx_132b"))
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, num_experts=E, top_k=k,
                                      capacity_factor=cf))


def _dense_reference(params, cfg, x):
    """No-capacity dense MoE: every token runs its top-k experts exactly."""
    m = cfg.moe
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(gates, m.top_k)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    E = m.num_experts
    for e in range(E):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        ye = h @ params["w_down"][e]
        w = jnp.sum(jnp.where(idx_k == e, gate_k, 0.0), axis=-1)
        out = out + ye.astype(jnp.float32) * w[..., None]
    if m.shared_expert:
        from repro.models import layers
        out = out + layers.mlp(params["shared"], x).astype(jnp.float32)
    return out.astype(x.dtype)


@pytest.mark.parametrize("E,k", [(4, 1), (4, 2), (8, 3)])
def test_moe_matches_dense_reference_when_no_drops(E, k):
    cfg = _cfg(E=E, k=k, cf=float(E))   # capacity = S*k: nothing dropped
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe.moe_ffn(params, cfg, x)
    ref = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)
    assert np.isfinite(float(aux["load_balance"]))
    assert float(aux["load_balance"]) >= 1.0 - 1e-3   # ≥1 by Cauchy-Schwarz


def test_dropless_serving_equals_dense_reference():
    cfg = _cfg(E=4, k=2, cf=0.1)        # brutal capacity...
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    out, _ = moe.moe_ffn(params, cfg, x, dropless=True)   # ...but dropless
    ref = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_capacity_drops_are_earliest_token_wins():
    """With capacity C, each expert keeps its first C routed tokens (GShard
    sequential-assignment semantics; our stable argsort reproduces it)."""
    E, C = 2, 4
    idx_k = jnp.zeros((1, 16, 1), jnp.int32)        # all 16 tokens -> expert 0
    slot, token_of_slot = moe._assign_slots(idx_k, E, C)
    # first C tokens get slots 0..C-1; the rest are dropped (slot == E*C)
    assert slot[0, :C].tolist() == [0, 1, 2, 3]
    assert (np.asarray(slot[0, C:]) == E * C).all()
    assert token_of_slot[0, :C].tolist() == [0, 1, 2, 3]


def test_moe_aux_losses_balanced_router():
    cfg = _cfg(E=4, k=1)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # uniform router logits => perfectly balanced => load_balance ≈ 1
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, cfg.d_model))
    _, aux = moe.moe_ffn(params, cfg, x)
    assert abs(float(aux["load_balance"]) - 1.0) < 0.3


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), E=st.sampled_from([2, 4, 8]),
       k=st.integers(1, 3))
def test_property_slot_assignment_bijective(seed, E, k):
    """Non-dropped (token,choice) pairs map to DISTINCT slots, and the
    inverse map agrees."""
    rng = np.random.default_rng(seed)
    S = 24
    idx = jnp.asarray(rng.integers(0, E, size=(1, S, k)), jnp.int32)
    C = 8
    slot, token_of_slot = moe._assign_slots(idx, E, C)
    s = np.asarray(slot[0])
    kept = s[s < E * C]
    assert len(np.unique(kept)) == len(kept)          # injective
    tos = np.asarray(token_of_slot[0])
    for f, sl in enumerate(s):
        if sl < E * C:
            assert tos[sl] == f // k                  # inverse consistent


def test_moe_gradients_flow():
    cfg = _cfg()
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        out, aux = moe.moe_ffn(p, cfg, x)
        return jnp.sum(out ** 2) + aux["load_balance"]
    g = jax.grad(loss)(params)
    gr = float(jnp.sum(jnp.abs(g["router"])))
    ge = float(jnp.sum(jnp.abs(g["w_gate"])))
    assert np.isfinite(gr) and gr > 0     # router learns via gate weights
    assert np.isfinite(ge) and ge > 0
